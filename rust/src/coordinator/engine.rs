//! The multi-session serving engine: N users, one contended edge.
//!
//! The paper's testbed serves a single device against a single edge
//! server; this module generalizes that loop into the crate's serving
//! core (DESIGN.md §6).  A [`Session`] owns one user's complete state —
//! boxed [`Policy`], frame source (video stream + key-frame detector),
//! per-network [`FeatureScale`]/context cache, and per-session
//! [`Metrics`] — while the [`Engine`] multiplexes all sessions over a
//! **shared edge** in lockstep rounds:
//!
//! 1. *select phase* — every session ticks its own uplink/workload,
//!    classifies its next frame, and asks its policy for a partition
//!    point, under the edge-load estimate from the previous round;
//! 2. *realize phase* — the engine counts how many sessions actually
//!    offloaded (k_t), sets every environment's edge-load factor to
//!    `Contention::factor(k_t)`, optionally queues each ψ_p through the
//!    [`SharedIngress`] FIFO, realizes the noisy delays, and feeds each
//!    policy its own feedback.
//!
//! Because the realized edge delay depends on k_t, the sessions' bandits
//! genuinely interact (the CANS regime): one learner's decision to
//! offload degrades every other learner's offloading arms.  With one
//! session and [`Contention::none`] the rounds reduce *bit-identically*
//! to the seed's single-stream experiment loop — `experiment::run` and
//! `pipeline::serve` are thin wrappers over the phase functions here.
//!
//! The realize phase has two modes (DESIGN.md §7).  The default
//! [`SchedulerConfig::lockstep_fifo`] keeps the PR 1 rounds above,
//! byte for byte.  Any other scheduler config routes offloads through
//! the event-driven [`crate::edge`] server instead: each ψ becomes an
//! [`EdgeJob`] on the fleet's virtual clock, contention is realized as
//! waiting-room delay plus cross-session batch amortization (not a
//! multiplicative factor), the waiting room may reject overflow back to
//! on-device execution, and executor backlog carries across rounds so
//! offloads contend when they overlap in *time*, not round index.
//!
//! With a **queue signal** ([`EngineConfig::queue_signal`]; DESIGN.md
//! §9) the select phase stops pretending the edge is the lockstep
//! `factor(k)` multiplier: a deterministic [`EdgeEstimate`] is frozen
//! from the live queue before each round, every policy sees the per-arm
//! predicted wait as known delay (μLinUCB additionally regresses over
//! the widened queue-feature context under `full`), and the records
//! carry an **event-clock oracle** — the chosen arm at its realized
//! mean versus every candidate replayed against the frozen snapshot —
//! from which `Summary::event_regret_ms` accumulates.
//!
//! Both phases are **sharded** across a fixed-size worker pool
//! ([`EngineConfig::workers`]; DESIGN.md §8): sessions split into
//! contiguous ranges, each worker advances its range independently, and
//! everything cross-session — the shared-ingress pass, edge-scheduler
//! admission, batch formation — runs on the main thread in canonical
//! *(arrival time, session id)* order via the deterministic
//! [`EventQueue`].  Per-session RNG streams ([`Rng::stream_seed`]) and
//! the canonical merge make the sharded engine bit-identical to the
//! single-threaded one at any worker count (pinned in
//! `rust/tests/fleet.rs` and `rust/tests/scheduler.rs`).

use super::metrics::{FleetSummary, FrameRecord, Metrics, Summary};
use super::pool::{shard_len, WorkerPool};
use crate::bandit::policy::argmin;
use crate::bandit::{
    FrameContext, Policy, PolicySnapshot, PolicyStore, Privileged, RidgeSlotMut, StoreSliceMut,
};
use crate::config::Config;
use crate::edge::{
    EdgeEstimate, EdgeJob, EdgeScheduler, EventQueue, Outcome, QueueSignal, QueueStats, Scheduled,
    SchedulerConfig,
};
use crate::models::features::{QUEUE_LOAD_FEATURE, QUEUE_MERGE_FEATURE};
use crate::models::{features, FeatureScale, FeatureVector};
use crate::simulator::{Contention, Environment, SharedIngress};
use crate::telemetry::{EventKind, Phase, PhaseClock, TraceEvent, TraceRing, Tracer};
use crate::util::rng::Rng;
use crate::util::stats::percentile;
use crate::video::{Frame, KeyframeDetector, VideoStream, Weights};
use std::sync::Mutex;
use std::time::Instant;

/// How frame weights L_t are produced for one session.
pub enum FrameSource {
    /// Every frame gets the same (non-key) weight — experiments where key
    /// frames are irrelevant.
    Uniform { weight: f64 },
    /// A synthetic video stream with SSIM key-frame detection
    /// (Fig 15; also the default serving configuration).
    Video { stream: VideoStream, detector: KeyframeDetector },
}

impl FrameSource {
    pub fn uniform() -> FrameSource {
        FrameSource::Uniform { weight: 0.2 }
    }

    pub fn video(seed: u64, ssim_threshold: f64, weights: Weights) -> FrameSource {
        FrameSource::Video {
            stream: VideoStream::new(64, 64, seed),
            detector: KeyframeDetector::new(ssim_threshold, weights),
        }
    }

    /// (is_key, weight) for the next frame.
    pub fn next(&mut self) -> (bool, f64) {
        let (_, is_key, weight) = self.next_with_frame();
        (is_key, weight)
    }

    /// Next frame with its pixels — the real serving path needs the
    /// tensor, the simulator only the classification.  `Uniform` sources
    /// yield no pixels.
    pub fn next_with_frame(&mut self) -> (Option<Frame>, bool, f64) {
        match self {
            FrameSource::Uniform { weight } => (None, false, *weight),
            FrameSource::Video { stream, detector } => {
                let frame = stream.next_frame();
                let c = detector.classify(&frame);
                (Some(frame), c.is_key, c.weight)
            }
        }
    }

    /// Serialize the source's resume cursor for hibernation (byte-cost
    /// cold state, DESIGN.md §14).  `Uniform` is stateless beyond its
    /// weight; `Video` packs the stream generator position and the
    /// detector's reference-frame cursor.
    pub fn pack_cursor(&self, out: &mut Vec<u8>) {
        match self {
            FrameSource::Uniform { weight } => {
                crate::util::bytes::put_u64(out, 0);
                crate::util::bytes::put_f64(out, *weight);
            }
            FrameSource::Video { stream, detector } => {
                crate::util::bytes::put_u64(out, 1);
                stream.pack_cursor(out);
                detector.pack_cursor(out);
            }
        }
    }

    /// Restore a cursor packed by [`FrameSource::pack_cursor`] into this
    /// source (the wake-side shell must be variant-compatible).
    pub fn unpack_cursor(&mut self, r: &mut crate::util::bytes::Reader<'_>) {
        let tag = r.take_u64();
        match (tag, self) {
            (0, FrameSource::Uniform { weight }) => *weight = r.take_f64(),
            (1, FrameSource::Video { stream, detector }) => {
                stream.unpack_cursor(r);
                detector.unpack_cursor(r);
            }
            (tag, _) => panic!("frame-source cursor variant mismatch (tag {tag})"),
        }
    }
}

/// One session's pending decision within a round.
#[derive(Debug, Clone)]
pub struct Decision {
    pub p: usize,
    pub is_key: bool,
    pub weight: f64,
    /// Policy's pre-feedback prediction for the chosen arm (None for
    /// p = P or policies without a model) — the honest Fig 9 curve.
    pub predicted_edge_ms: Option<f64>,
}

/// One user's complete serving state.
pub struct Session {
    pub id: usize,
    pub policy: Box<dyn Policy>,
    /// This session's private environment: its own uplink and noise
    /// stream; the edge *profile* is shared with the fleet and coupled
    /// through the engine's contention factor.
    pub env: Environment,
    pub source: FrameSource,
    pub metrics: Metrics,
    /// Per-network feature normalization (cached at session creation).
    pub scale: FeatureScale,
    front: Vec<f64>,
    contexts: Vec<FeatureVector>,
    expected: Vec<f64>,
    /// Per-arm forecast queue wait scratch (queue-signal modes).
    waits: Vec<f64>,
    /// The session's SoA store slot while resident (`usize::MAX` when
    /// detached — mid-migration, hibernated, or post-`into_sessions`).
    pub(crate) slot: usize,
    /// Whether the session participates in rounds.  Idle residents keep
    /// their store slot but are skipped by every phase (O(active) rounds,
    /// DESIGN.md §14).
    pub(crate) active: bool,
}

impl Session {
    pub fn new(id: usize, policy: Box<dyn Policy>, env: Environment, source: FrameSource) -> Session {
        let scale = FeatureScale::for_network(&env.net);
        let contexts = features::context_vectors(&env.net, &scale);
        let front = env.front_delays().to_vec();
        let expected = vec![0.0; env.num_partitions() + 1];
        let waits = vec![0.0; env.num_partitions() + 1];
        Session {
            id,
            policy,
            env,
            source,
            metrics: Metrics::new(),
            scale,
            front,
            contexts,
            expected,
            waits,
            slot: usize::MAX,
            active: true,
        }
    }

    /// Cheap per-session diagnostics (fleet tables).  Only valid while
    /// the session is **detached** (self-contained policy state, e.g.
    /// mid-migration or after [`Engine::into_sessions`]); a resident
    /// session's ridge state lives in the engine's SoA store, so resident
    /// snapshots go through [`Engine::policy_snapshot`] instead
    /// (store-backed learners panic here by design).
    pub fn snapshot(&self) -> PolicySnapshot {
        self.policy.snapshot()
    }

    /// Summary of everything this session served so far.
    pub fn summary(&self) -> Summary {
        self.metrics.summary(self.env.num_partitions())
    }
}

/// One decision through a policy without a simulator environment — the
/// select step shared by the simulated rounds and the real PJRT pipeline.
/// `queue_wait_ms` is the per-arm forecast wait (empty = queue signal
/// off, the legacy context).  `slot` is the session's SoA store slot when
/// the caller is the fleet engine (DESIGN.md §11); `None` drives the
/// policy's owned state (single-stream experiment, real pipeline).
#[allow(clippy::too_many_arguments)]
pub fn decide(
    policy: &mut dyn Policy,
    mut slot: Option<&mut RidgeSlotMut<'_>>,
    t: usize,
    is_key: bool,
    weight: f64,
    front: &[f64],
    contexts: &[FeatureVector],
    rate_mbps: f64,
    expected_totals: Option<&[f64]>,
    queue_wait_ms: &[f64],
) -> Decision {
    let ctx = FrameContext {
        t,
        weight,
        front_delays: front,
        contexts,
        queue_wait_ms,
        privileged: Privileged { rate_mbps, expected_totals },
    };
    let p = policy.select_in(&ctx, slot.as_mut().map(|s| &mut **s));
    let p_max = front.len() - 1;
    assert!(p <= p_max, "policy {} chose invalid arm {p}", policy.name());
    // Record the prediction BEFORE feedback (honest Fig 9 curve).  The
    // model predicts the wait-stripped edge leg under the queue signal,
    // so the recorded prediction adds the known forecast wait back —
    // comparable to `true_edge_ms` in every mode.
    let predicted_edge_ms = if p == p_max {
        None
    } else {
        policy
            .predict_edge_delay_in(&contexts[p], slot.as_ref().map(|s| s.read()))
            .map(|d| d + ctx.queue_wait(p))
    };
    Decision { p, is_key, weight, predicted_edge_ms }
}

/// Frozen cross-session inputs of one engine round: the pre-round queue
/// forecast, the queue-signal mode, and the capture-clock/deadline
/// scalars.  Computed once on the main thread and `Copy`, so every
/// sharded worker reads the same bits — worker count cannot perturb a
/// round (DESIGN.md §8/§9).
#[derive(Debug, Clone, Copy)]
pub(crate) struct RoundInfo {
    pub estimate: EdgeEstimate,
    pub signal: QueueSignal,
    pub frame_interval_ms: f64,
    pub stagger_ms: f64,
    /// Herding mitigation (DESIGN.md §10): amplitude of the deterministic
    /// per-session phase offset folded into the *published* forecast wait
    /// (0 = off, bit-identical to the unstaggered transcripts).
    pub signal_stagger_ms: f64,
    /// Per-frame completion budget for deadline-miss accounting
    /// (∞ = none); counted in every scheduler mode, independent of EDF.
    pub deadline_ms: f64,
    /// Event-clock accounting on (the event scheduler is active)?
    pub event: bool,
}

impl RoundInfo {
    /// The single-stream/lockstep degenerate case: no queue, no signal,
    /// no deadline — every new code path is dormant.
    pub(crate) fn lockstep() -> RoundInfo {
        RoundInfo {
            estimate: EdgeEstimate::idle(),
            signal: QueueSignal::Off,
            frame_interval_ms: 0.0,
            stagger_ms: 0.0,
            signal_stagger_ms: 0.0,
            deadline_ms: f64::INFINITY,
            event: false,
        }
    }

    /// When this frame was captured on session `id`'s device clock.
    fn capture_ms(&self, t: usize, id: usize) -> f64 {
        t as f64 * self.frame_interval_ms + self.stagger_ms * id as f64
    }
}

/// Select phase for one simulated session: advance its environment and
/// frame source, build the decision context, and take the policy's
/// decision.
///
/// With the queue signal **off** the context is the legacy lockstep one
/// — `Contention::factor(k)` on the environment, expected totals from
/// the multiplicative model — byte for byte.  With the signal on, the
/// frozen [`RoundInfo`] forecast *replaces* the factor: the expected
/// totals become `d_p^f + tx + ŵ_p + amortized solo service`, the
/// per-arm waits are exposed to every policy as known delay, and under
/// [`QueueSignal::Full`] the queue feature dimensions are written into
/// each off-device arm's context vector for the learner to regress on.
#[allow(clippy::too_many_arguments)]
pub(crate) fn select_one(
    policy: &mut dyn Policy,
    slot: Option<&mut RidgeSlotMut<'_>>,
    env: &mut Environment,
    source: &mut FrameSource,
    front: &[f64],
    contexts: &mut [FeatureVector],
    expected: &mut [f64],
    waits: &mut [f64],
    t: usize,
    concurrent_estimate: usize,
    contention: &Contention,
    round: &RoundInfo,
    session_id: usize,
) -> Decision {
    let (is_key, weight) = prep_select(
        env,
        source,
        front,
        contexts,
        expected,
        waits,
        t,
        concurrent_estimate,
        contention,
        round,
        session_id,
    );
    let queue_wait_ms: &[f64] = if round.signal.is_off() { &[] } else { waits };
    decide(
        policy,
        slot,
        t,
        is_key,
        weight,
        front,
        contexts,
        env.current_rate_mbps(),
        Some(&*expected),
        queue_wait_ms,
    )
}

/// Everything in [`select_one`] *except* the policy decision: advance the
/// environment and frame source, fill the expected totals and per-arm
/// forecast waits, and (under [`QueueSignal::Full`]) write the queue
/// features into the context vectors.  Returns `(is_key, weight)` for the
/// frame.  The arm-major batched select runs this prep per session, then
/// replaces the scalar `decide` with the shard-wide batched scoring sweep
/// — same inputs, same bits (DESIGN.md §13).
#[allow(clippy::too_many_arguments)]
fn prep_select(
    env: &mut Environment,
    source: &mut FrameSource,
    front: &[f64],
    contexts: &mut [FeatureVector],
    expected: &mut [f64],
    waits: &mut [f64],
    t: usize,
    concurrent_estimate: usize,
    contention: &Contention,
    round: &RoundInfo,
    session_id: usize,
) -> (bool, f64) {
    env.tick(t);
    if round.signal.is_off() {
        env.set_contention_factor(contention.factor(concurrent_estimate));
        let (is_key, weight) = source.next();
        for (p, v) in expected.iter_mut().enumerate() {
            *v = env.expected_total(p);
        }
        return (is_key, weight);
    }
    // Queue-aware select: contention reaches the policies through the
    // virtual-clock forecast, not a multiplicative factor.
    env.set_contention_factor(1.0);
    let (is_key, weight) = source.next();
    let est = &round.estimate;
    let capture_ms = round.capture_ms(t, session_id);
    let p_max = env.num_partitions();
    let rate = env.current_rate_mbps();
    // Herding stagger: a per-session golden-ratio phase offset on the
    // *published* wait, so identical learners stop reacting to the same
    // idle forecast in the same round (DESIGN.md §10).  0 ms (default)
    // adds exactly +0.0 per arm — the unstaggered transcripts are
    // bit-identical — and the realize-phase accounting (event oracle,
    // realized waits) never sees the offset.
    let stagger_ms = round.signal_stagger_ms * crate::edge::signal_phase(session_id);
    for p in 0..=p_max {
        if p == p_max {
            waits[p] = 0.0;
            expected[p] = front[p];
            continue;
        }
        let tx = crate::simulator::tx_delay_ms(env.psi_bytes(p), rate, env.rtt_ms);
        let wait = est.wait_ms(capture_ms + front[p] + tx) + stagger_ms;
        waits[p] = wait;
        expected[p] = front[p] + tx + wait + est.service_ms(env.solo_backend_ms(p));
    }
    if round.signal == QueueSignal::Full {
        // The on-device arm (index p_max) stays the zero vector.
        for x in contexts.iter_mut().take(p_max) {
            x[QUEUE_MERGE_FEATURE] = est.merge_probability;
            x[QUEUE_LOAD_FEATURE] = est.amortization - 1.0;
        }
    }
    (is_key, weight)
}

/// How one frame's edge leg realizes (see [`realize_one`]).
#[derive(Debug, Clone, Copy)]
pub(crate) enum EdgeLeg {
    /// PR 1 lockstep: draw the session's noise on the contention-factored
    /// compute + tx mean, then add the precomputed ingress queueing on
    /// top.  Also covers MO frames (zero edge leg, no draw) in every
    /// scheduler mode.
    Lockstep,
    /// Event-driven scheduler: the full mean edge leg (tx + ingress +
    /// waiting room + amortized service — or tx + on-device fallback for
    /// a rejected offload) was resolved on the virtual clock; draw the
    /// session's noise on it.
    Event { mean_ms: f64, rejected: bool },
}

/// How [`realize_one`] delivers learner feedback for an offloaded frame.
///
/// The scalar path observes inline ([`Feedback::Observe`], through
/// `Policy::observe_in`).  The arm-major batched observe phase instead
/// *gathers* each session's `(x, d^e)` pair ([`Feedback::Defer`]) so the
/// whole shard's ridge updates run through the store's batched kernels
/// afterwards — in session order, so per-slot op order (and therefore
/// every learner bit) is unchanged.  The feedback value handed to the
/// sink is exactly what `observe_in` would have received; nothing else
/// in [`realize_one`] reads policy state, so deferring cannot perturb
/// the record (DESIGN.md §13).
pub(crate) enum Feedback<'a> {
    /// Feed the policy inline (the scalar path).
    Observe,
    /// Hand `(context, feedback_ms)` to the sink; the caller owes the
    /// ridge update + commit.
    Defer(&'a mut dyn FnMut(&FeatureVector, f64)),
}

/// Realize phase for one simulated session: apply the fleet's actual
/// concurrency, draw the noisy delay for the frame's [`EdgeLeg`], feed
/// the policy, and record ground-truth metrics.  `queue_wait_ms` (edge
/// NIC + waiting room) and `batch_size` are recorded; under
/// [`EdgeLeg::Lockstep`] the queueing term is additionally added to the
/// drawn delay (the PR 1 shared-ingress semantics).
///
/// Two accounting layers land in the record (DESIGN.md §9):
///
/// * the **legacy lockstep oracle** (`expected_ms`/`oracle_*`) — the
///   `factor(k)` model, unchanged in every mode so transcripts stay
///   comparable and the `--queue-signal off` pins hold byte-for-byte;
/// * the **event-clock oracle** (`event_*`) — when the event scheduler
///   is active, the chosen arm is valued at its *true realized mean*
///   and every other candidate replays against the round's frozen
///   queue snapshot, so `event_oracle_ms ≤` the noise-free realized
///   delay on every frame (property-tested).
///
/// Under a queue signal, learner feedback is the realized edge delay
/// **minus the realized queue wait**: the wait is known (it entered the
/// score as known delay), so the model regresses the tx + service
/// residual instead of conflating it with queue luck.
#[allow(clippy::too_many_arguments)]
pub(crate) fn realize_one(
    policy: &mut dyn Policy,
    slot: Option<&mut RidgeSlotMut<'_>>,
    env: &mut Environment,
    metrics: &mut Metrics,
    front: &[f64],
    contexts: &[FeatureVector],
    expected: &mut [f64],
    decision: &Decision,
    t: usize,
    concurrent: usize,
    contention: &Contention,
    queue_wait_ms: f64,
    batch_size: usize,
    leg: EdgeLeg,
    round: &RoundInfo,
    session_id: usize,
    feedback_mode: Feedback<'_>,
) {
    env.set_contention_factor(contention.factor(concurrent));
    for (p, v) in expected.iter_mut().enumerate() {
        *v = env.expected_total(p);
    }
    let p_max = env.num_partitions();
    let p = decision.p;
    let (realized_edge, true_edge_ms, rejected) = match leg {
        EdgeLeg::Lockstep => {
            let mut d = if p == p_max { 0.0 } else { env.observe_edge_delay(p) };
            if p != p_max {
                // Queueing behind other sessions' payloads at the edge
                // NIC is part of the d^e feedback the policy learns from.
                d += queue_wait_ms;
            }
            (d, env.expected_edge_delay(p), false)
        }
        EdgeLeg::Event { mean_ms, rejected } => {
            debug_assert!(p != p_max, "MO frames realize via EdgeLeg::Lockstep");
            (env.noisy(mean_ms), mean_ms, rejected)
        }
    };
    let delay_ms = front[p] + realized_edge;
    if p != p_max {
        let feedback = if round.signal.is_off() {
            realized_edge
        } else {
            (realized_edge - queue_wait_ms).max(0.0)
        };
        match feedback_mode {
            Feedback::Observe => policy.observe_in(p, &contexts[p], feedback, slot),
            Feedback::Defer(sink) => sink(&contexts[p], feedback),
        }
    }
    let oracle_p = argmin(expected);
    let (event_expected_ms, event_oracle_p, event_oracle_ms) = if round.event {
        // Chosen arm at its realized mean; counterfactuals against the
        // frozen pre-round snapshot.  Allocation-free running min.
        let mine = match leg {
            EdgeLeg::Lockstep => front[p], // only MO realizes this leg in event mode
            EdgeLeg::Event { mean_ms, .. } => front[p] + mean_ms,
        };
        let est = &round.estimate;
        let capture_ms = round.capture_ms(t, session_id);
        let rate = env.current_rate_mbps();
        let mut best_p = p;
        let mut best = mine;
        for q in 0..=p_max {
            if q == p {
                continue;
            }
            let cf = if q == p_max {
                front[q]
            } else {
                let tx = crate::simulator::tx_delay_ms(env.psi_bytes(q), rate, env.rtt_ms);
                front[q] + est.edge_delay_ms(tx, capture_ms + front[q] + tx, env.solo_backend_ms(q))
            };
            if cf < best {
                best = cf;
                best_p = q;
            }
        }
        (mine, best_p, best)
    } else {
        // Lockstep rounds: the event clock degenerates to the legacy
        // accounting (one oracle, two names).
        (expected[p], oracle_p, expected[oracle_p])
    };
    let deadline_miss = round.deadline_ms.is_finite() && delay_ms > round.deadline_ms;
    metrics.push(FrameRecord {
        t,
        p,
        is_key: decision.is_key,
        weight: decision.weight,
        delay_ms,
        expected_ms: expected[p],
        oracle_p,
        oracle_ms: expected[oracle_p],
        rate_mbps: env.current_rate_mbps(),
        predicted_edge_ms: decision.predicted_edge_ms,
        true_edge_ms,
        queue_wait_ms,
        batch_size: if p == p_max { 0 } else { batch_size },
        rejected,
        event_expected_ms,
        event_oracle_p,
        event_oracle_ms,
        deadline_miss,
    });
}

/// Arm-major batched-select mode (`--select-batch`; DESIGN.md §13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectBatch {
    /// Force the batched driver.  Mixed fleets still work: sessions whose
    /// policy is not store-backed run the scalar fallback inside the
    /// batched shard pass.
    On,
    /// Force the legacy scalar per-session path.
    Off,
    /// Batched exactly when every resident session is store-backed (the
    /// default): an all-μLinUCB fleet gets the arm-major kernels, a
    /// mixed or baseline fleet keeps the scalar loop.
    Auto,
}

impl SelectBatch {
    /// Parse a `--select-batch` value (config/CLI entry point).
    pub fn by_name(name: &str) -> Option<SelectBatch> {
        match name {
            "on" => Some(SelectBatch::On),
            "off" => Some(SelectBatch::Off),
            "auto" => Some(SelectBatch::Auto),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SelectBatch::On => "on",
            SelectBatch::Off => "off",
            SelectBatch::Auto => "auto",
        }
    }
}

/// Engine knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Logical frame interval (ms) — spaces rounds on the shared virtual
    /// clock (ingress + edge scheduler).
    pub frame_interval_ms: f64,
    /// Shared-edge contention model.  Lockstep rounds apply
    /// `factor(k_t)` multiplicatively to every offloader; the
    /// event-driven scheduler uses the same curve as the queue's batch
    /// service-time model (see [`crate::edge::batcher`]).
    pub contention: Contention,
    /// Shared edge-ingress bandwidth (None = ingress not modelled; each
    /// session's own uplink is then the only network leg).
    pub ingress_mbps: Option<f64>,
    /// Edge-server scheduling discipline.  The default
    /// ([`SchedulerConfig::lockstep_fifo`]) reproduces the PR 1 rounds
    /// bit-identically; anything else routes offloads through the
    /// event-driven [`EdgeScheduler`].
    pub scheduler: SchedulerConfig,
    /// Worker-pool size for the sharded select/observe phases (1 = run
    /// everything on the calling thread).  Sessions shard across workers
    /// in contiguous ranges; because every session owns its policy, RNG
    /// streams, and metrics, and all cross-session coupling happens on
    /// the main thread in canonical (timestamp, session) order, the
    /// engine's output is **bit-identical at every worker count**
    /// (pinned in `rust/tests/fleet.rs`; DESIGN.md §8).
    pub workers: usize,
    /// How much of the pre-round queue forecast the select phase
    /// exposes to the policies (DESIGN.md §9).  [`QueueSignal::Off`]
    /// (the default) keeps the legacy lockstep decision context, pinned
    /// bit-identical to the PR 2/3 transcripts; `Wait`/`Full` require
    /// the event-driven scheduler.
    pub queue_signal: QueueSignal,
    /// Herding mitigation (`--signal-stagger`; DESIGN.md §10): amplitude
    /// in ms of the deterministic per-session phase offset
    /// ([`crate::edge::signal_phase`]) folded into the published
    /// forecast wait.  0 (the default) is pinned bit-identical to the
    /// unstaggered transcripts; > 0 requires an active queue signal.
    pub signal_stagger_ms: f64,
    /// Arm-major batched select/observe (`--select-batch`; DESIGN.md
    /// §13).  [`SelectBatch::Auto`] (the default) drives the shard
    /// phases through the SoA store's batched kernels whenever every
    /// resident session is store-backed, and falls back to the scalar
    /// per-session loop otherwise.  Both paths are pinned bit-identical
    /// at every worker count (`rust/tests/fleet.rs`), so the knob is a
    /// pure performance escape hatch.
    pub select_batch: SelectBatch,
    /// Structured event-trace ring capacity per shard (DESIGN.md §12).
    /// 0 (the default) disables tracing entirely — the engine holds no
    /// tracer and every emission site is one `Option` branch.  > 0
    /// preallocates rings of this many [`TraceEvent`]s for the main
    /// thread and each pool worker; once full, the oldest events are
    /// overwritten (and counted) rather than allocating.  Tracing never
    /// perturbs the simulation: the round transcripts are bit-identical
    /// with tracing on and off (pinned in `rust/tests/fleet.rs`).
    pub trace_capacity: usize,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig {
            frame_interval_ms: 1e3 / 30.0,
            contention: Contention::none(),
            ingress_mbps: None,
            scheduler: SchedulerConfig::lockstep_fifo(),
            workers: 1,
            queue_signal: QueueSignal::Off,
            signal_stagger_ms: 0.0,
            select_batch: SelectBatch::Auto,
            trace_capacity: 0,
        }
    }
}

/// `(queue_wait_ms, batch_size, edge leg)` — one session's realize input.
type Leg = (f64, usize, EdgeLeg);

/// Per-round scratch buffers, reused across rounds so a steady-state
/// single-threaded (`workers = 1`) engine round performs no heap
/// allocation on the select/observe path — asserted by the hotpath
/// bench's allocation counter.  Sharded rounds additionally build O(W)
/// shard handles per phase (plus channel nodes in the pool handoff);
/// see DESIGN.md §8 scaling caveats.
#[derive(Default)]
struct StepScratch {
    /// The round's **active-set index**: list positions of the sessions
    /// that participate this round, ascending (== ascending store slot).
    /// Every other per-round buffer below is parallel to this index, so
    /// a steady-state round is O(active) in policy math and edge
    /// traffic, not O(resident) (DESIGN.md §14).
    act: Vec<usize>,
    decisions: Vec<Decision>,
    /// Canonical offload-merge queue: entries are `(active index, ψ
    /// bytes)` keyed by NIC-arrival time with the **global session id**
    /// as the tie key — the deterministic merge order every worker count
    /// (and any residency layout) reproduces.
    arrivals: EventQueue<(usize, usize)>,
    legs: Vec<Leg>,
    tx_ms: Vec<f64>,
    ingress_wait: Vec<f64>,
    rejected: Vec<bool>,
    outcomes: Vec<Option<Outcome>>,
    scheduled: Vec<Scheduled>,
}

impl StepScratch {
    /// Grow every active-set-parallel buffer to at least `cap` entries —
    /// the churn-envelope pre-sizing [`Engine::reserve_sessions`] applies
    /// so a fluctuating active set never reallocates mid-round.
    fn reserve(&mut self, cap: usize) {
        BatchScratch::grow(&mut self.act, cap);
        BatchScratch::grow(&mut self.decisions, cap);
        BatchScratch::grow(&mut self.legs, cap);
        BatchScratch::grow(&mut self.tx_ms, cap);
        BatchScratch::grow(&mut self.ingress_wait, cap);
        BatchScratch::grow(&mut self.rejected, cap);
        BatchScratch::grow(&mut self.outcomes, cap);
        BatchScratch::grow(&mut self.scheduled, cap);
        self.arrivals.reserve(cap);
    }
}

/// Where one session stands inside the batched select passes.
#[derive(Debug, Clone, Copy, Default)]
enum Plan {
    /// Decision already written (scalar fallback, warm-up, or final pick).
    #[default]
    Done,
    /// Prep + prelude ran; the session still needs its θ̂ cache refreshed
    /// and either the warm-up finalization or the scoring sweep.
    Pending { is_key: bool, weight: f64, evicted: bool, warmup: Option<usize> },
    /// Scoring coefficients fixed; the arm-major sweep fills this
    /// session's score row, then the argmin pass decides.
    Score { is_key: bool, weight: f64, conf_scale: f64, alpha: f64 },
}

/// Per-worker scratch arenas for the arm-major batched select/observe
/// (DESIGN.md §13).  Pre-sized by [`Engine::reserve`] so the batched
/// steady state allocates nothing — asserted by the hotpath bench's
/// `alloc/engine_armmajor_steady_state` audit.
#[derive(Default)]
struct BatchScratch {
    /// θ̂ per active session, materialized by the gathered `k_matvec`
    /// sweep (`act × d`, row per active entry).
    thetas: Vec<f64>,
    /// Arm-major score matrix (`act × max_arms`, row per active entry).
    scores: Vec<f64>,
    /// Per-active-entry pass state.
    plans: Vec<Plan>,
    /// Window-relative store slot per active entry, filled in pass 1 —
    /// the gather index the batched kernels iterate.  Free slots and
    /// idle residents inside the window are simply never listed.
    jw: Vec<usize>,
    /// Gathered window evictions: window slot / flattened context /
    /// feedback, in per-session eviction order (batched downdate input).
    ev_j: Vec<usize>,
    ev_x: Vec<f64>,
    ev_y: Vec<f64>,
    /// Gathered observe feedback, one entry max per session per round
    /// (batched update input; drift-consumed entries are compacted out).
    /// `up_j` holds the window slot (the kernel index); `up_i` the
    /// active-entry index (the session back-reference) — compacted in
    /// lockstep.
    up_j: Vec<usize>,
    up_i: Vec<usize>,
    up_x: Vec<f64>,
    up_y: Vec<f64>,
    /// Refresh/reset counters read before the deferred observes so the
    /// trace pass can emit the same transitions as the scalar path.
    ops_before: Vec<usize>,
    resets_before: Vec<usize>,
}

impl BatchScratch {
    /// Grow `v`'s capacity to at least `cap` (no-op once steady).
    fn grow<T>(v: &mut Vec<T>, cap: usize) {
        if v.capacity() < cap {
            v.reserve(cap - v.len());
        }
    }

    /// Pre-size for a shard of `per` sessions, ridge dimension `d`, and
    /// at most `arms` arms per session.
    fn reserve(&mut self, per: usize, d: usize, arms: usize) {
        Self::grow(&mut self.thetas, per * d);
        Self::grow(&mut self.scores, per * arms);
        Self::grow(&mut self.plans, per);
        Self::grow(&mut self.jw, per);
        Self::grow(&mut self.ev_j, per);
        Self::grow(&mut self.ev_x, per * d);
        Self::grow(&mut self.ev_y, per);
        Self::grow(&mut self.up_j, per);
        Self::grow(&mut self.up_i, per);
        Self::grow(&mut self.up_x, per * d);
        Self::grow(&mut self.up_y, per);
        Self::grow(&mut self.ops_before, per);
        Self::grow(&mut self.resets_before, per);
    }
}

/// Select step for one session (advance env/source, ask the policy).
/// `slot` is the session's slot in the engine's SoA policy store.
fn session_select(
    s: &mut Session,
    slot: Option<&mut RidgeSlotMut<'_>>,
    t: usize,
    k_estimate: usize,
    contention: &Contention,
    round: &RoundInfo,
) -> Decision {
    let id = s.id;
    let Session { policy, env, source, front, contexts, expected, waits, .. } = s;
    select_one(
        policy.as_mut(),
        slot,
        env,
        source,
        front,
        contexts,
        expected,
        waits,
        t,
        k_estimate,
        contention,
        round,
        id,
    )
}

/// Realize step for one session (draw the noisy delay, learn, record).
/// When tracing (`ring` is `Some`), the learner's refresh counter and
/// reset counter are read before and after the observe so the rare
/// `policy_refresh` / `policy_reset` transitions become trace events —
/// two O(1) reads per frame, nothing fed back into the simulation.
#[allow(clippy::too_many_arguments)]
fn session_realize(
    s: &mut Session,
    mut slot: Option<&mut RidgeSlotMut<'_>>,
    d: &Decision,
    leg: &Leg,
    t: usize,
    k: usize,
    contention: &Contention,
    round: &RoundInfo,
    ring: Option<&mut TraceRing>,
) {
    let id = s.id;
    let watch = ring.is_some();
    let ops_before =
        if watch { slot.as_ref().map_or(0, |sl| sl.read().ops_since_refresh()) } else { 0 };
    let resets_before = if watch { s.policy.reset_count() } else { 0 };
    let Session { policy, env, metrics, front, contexts, expected, .. } = s;
    realize_one(
        policy.as_mut(),
        slot.as_mut().map(|sl| &mut **sl),
        env,
        metrics,
        front,
        contexts,
        expected,
        d,
        t,
        k,
        contention,
        leg.0,
        leg.1,
        leg.2,
        round,
        id,
        Feedback::Observe,
    );
    if let Some(ring) = ring {
        let clock = round.capture_ms(t, id);
        let ops_after = slot.as_ref().map_or(0, |sl| sl.read().ops_since_refresh());
        let resets_after = policy.reset_count();
        if ops_after < ops_before && resets_after == resets_before {
            // The counter only moves backwards on a Cholesky refresh (or
            // a drift reset, reported as its own event below).
            ring.push(TraceEvent::new(
                EventKind::PolicyRefresh,
                t,
                Some(id),
                clock,
                ops_before as f64,
                0.0,
            ));
        }
        if resets_after > resets_before {
            ring.push(TraceEvent::new(
                EventKind::PolicyReset,
                t,
                Some(id),
                clock,
                resets_after as f64,
                0.0,
            ));
        }
    }
}

/// Arm-major batched select over one shard (DESIGN.md §13): the scalar
/// per-session loop decomposed into shard-wide passes so the ridge math
/// runs through the store's strided batch kernels.
///
/// Pass structure (per-session op order is preserved, so every learner
/// and transcript bit matches the scalar path exactly):
///
/// 1. per session: env/source prep, then the select prelude (window
///    evictions *gathered* instead of downdated inline; warm-up claim).
///    Non-store-backed sessions take the whole scalar `session_select`
///    here and are done.
/// 2. batched downdate of all gathered evictions (in gather order — each
///    slot sees its own evictions in its own order, slots are disjoint),
///    then one batched `k_matvec` sweep materializing every slot's θ̂.
/// 3. per session: refresh the policy's θ̂ cache from its arena row
///    (bit-identical to the scalar `theta_into`), finalize warm-up
///    decisions, fix scoring coefficients for the rest.
/// 4. the arm-major sweep: for each arm index, score it across all
///    still-scoring sessions (same per-score arithmetic as the scalar
///    `score_arms`, reading the θ̂ arena rows).
/// 5. per session: forced-exclusion argmin over its score row, then the
///    same post-pick prediction the scalar `decide` records.
#[allow(clippy::too_many_arguments)]
fn select_shard_batched(
    sessions: &mut [Session],
    pos_base: usize,
    act: &[usize],
    slot_base: usize,
    decisions: &mut [Decision],
    win: &mut StoreSliceMut<'_>,
    batchable: &[bool],
    sc: &mut BatchScratch,
    t: usize,
    k_estimate: usize,
    contention: &Contention,
    round: &RoundInfo,
) {
    let n = act.len();
    let d = win.dim();
    debug_assert_eq!(decisions.len(), n);
    sc.plans.clear();
    sc.plans.resize(n, Plan::Done);
    sc.jw.clear();
    sc.ev_j.clear();
    sc.ev_x.clear();
    sc.ev_y.clear();

    // Pass 1: prep + prelude (or the full scalar path for fallbacks).
    // `act` holds absolute list positions; the shard's sessions slice
    // starts at `pos_base` and its store window at slot `slot_base`.
    for a in 0..n {
        let pos = act[a];
        let jw = sessions[pos - pos_base].slot - slot_base;
        sc.jw.push(jw);
        if !batchable[pos] {
            let mut slot = win.slot_mut(jw);
            decisions[a] = session_select(
                &mut sessions[pos - pos_base],
                Some(&mut slot),
                t,
                k_estimate,
                contention,
                round,
            );
            continue;
        }
        let s = &mut sessions[pos - pos_base];
        let id = s.id;
        let Session { policy, env, source, front, contexts, expected, waits, .. } = s;
        let (is_key, weight) = prep_select(
            env,
            source,
            front,
            contexts,
            expected,
            waits,
            t,
            k_estimate,
            contention,
            round,
            id,
        );
        let p_max = env.num_partitions();
        let lu = policy.as_batched().expect("batchable sessions are store-backed LinUCB");
        let (ev_j, ev_x, ev_y) = (&mut sc.ev_j, &mut sc.ev_x, &mut sc.ev_y);
        let (evicted, warmup) = lu.batch_select_prelude(t, p_max, |x, y| {
            ev_j.push(jw);
            ev_x.extend_from_slice(x);
            ev_y.push(y);
        });
        sc.plans[a] = Plan::Pending { is_key, weight, evicted, warmup };
    }

    // Pass 2: expired window entries leave every touched slot at once,
    // then one gathered sweep materializes θ̂ for exactly the active
    // entries (O(active), not O(slots in the window)).
    if !sc.ev_j.is_empty() {
        win.downdate_batch_at(&sc.ev_j, &sc.ev_x, &sc.ev_y);
    }
    sc.thetas.clear();
    sc.thetas.resize(n * d, 0.0);
    win.theta_batch_at(&sc.jw, &mut sc.thetas);

    // Pass 3: θ̂ caches, warm-up finalization, scoring coefficients.
    let mut max_arms = 0;
    for a in 0..n {
        let Plan::Pending { is_key, weight, evicted, warmup } = sc.plans[a] else {
            continue;
        };
        let s = &mut sessions[act[a] - pos_base];
        let row = &sc.thetas[a * d..(a + 1) * d];
        let p_max = s.env.num_partitions();
        let lu = s.policy.as_batched().expect("batchable");
        if let Some(arm) = warmup {
            // The scalar path refreshes the cache on the warm-up return
            // only when the prelude evicted something.
            if evicted {
                lu.set_theta_cache(row);
            }
            let wait = if round.signal.is_off() { 0.0 } else { s.waits[arm] };
            let predicted_edge_ms = if arm == p_max {
                None
            } else {
                Some(win.slot_at(sc.jw[a]).predict(&s.contexts[arm]) + wait)
            };
            decisions[a] = Decision { p: arm, is_key, weight, predicted_edge_ms };
            sc.plans[a] = Plan::Done;
        } else {
            lu.set_theta_cache(row);
            let (conf_scale, alpha) = lu.batch_score_params(weight, &s.front);
            sc.plans[a] = Plan::Score { is_key, weight, conf_scale, alpha };
            max_arms = max_arms.max(s.front.len());
        }
    }

    // Pass 4: the arm-major scoring sweep — same per-cell arithmetic as
    // the scalar `score_arms`, iterated arm-outer so each arm index
    // streams across the shard's gathered θ̂/A⁻¹ rows.
    let stride = max_arms;
    sc.scores.clear();
    sc.scores.resize(n * stride, 0.0);
    for p in 0..max_arms {
        for a in 0..n {
            let Plan::Score { conf_scale, alpha, .. } = sc.plans[a] else {
                continue;
            };
            let s = &sessions[act[a] - pos_base];
            if p >= s.front.len() {
                continue;
            }
            let x = &s.contexts[p];
            let wait = if round.signal.is_off() { 0.0 } else { s.waits[p] };
            let pred = crate::bandit::linalg::dot(&sc.thetas[a * d..(a + 1) * d], x);
            let width = (conf_scale * win.slot_at(sc.jw[a]).confidence_sq(x)).max(0.0).sqrt();
            sc.scores[a * stride + p] = s.front[p] + wait + pred - alpha * width;
        }
    }

    // Pass 5: per-session argmin + the post-pick prediction.
    for a in 0..n {
        let Plan::Score { is_key, weight, .. } = sc.plans[a] else {
            continue;
        };
        let s = &mut sessions[act[a] - pos_base];
        let p_max = s.env.num_partitions();
        let row = &sc.scores[a * stride..a * stride + p_max + 1];
        let p = s
            .policy
            .as_batched()
            .expect("batchable")
            .batch_pick(t, row, p_max);
        debug_assert!(p <= p_max);
        let wait = if round.signal.is_off() { 0.0 } else { s.waits[p] };
        let predicted_edge_ms = if p == p_max {
            None
        } else {
            Some(win.slot_at(sc.jw[a]).predict(&s.contexts[p]) + wait)
        };
        decisions[a] = Decision { p, is_key, weight, predicted_edge_ms };
        sc.plans[a] = Plan::Done;
    }
}

/// Arm-major batched observe over one shard (DESIGN.md §13): realize
/// every frame with feedback *gathered*, drift-check each observation
/// against its pre-update slot, push the survivors through the store's
/// batched update, then commit bookkeeping — all in session order, so
/// per-slot op order matches the scalar loop bit for bit.  Refresh/reset
/// trace events are emitted in a final pass; [`Tracer::drain`] sorts
/// canonically, so the drained trace is identical to the scalar path's.
#[allow(clippy::too_many_arguments)]
fn observe_shard_batched(
    sessions: &mut [Session],
    pos_base: usize,
    act: &[usize],
    slot_base: usize,
    decisions: &[Decision],
    legs: &[Leg],
    win: &mut StoreSliceMut<'_>,
    batchable: &[bool],
    sc: &mut BatchScratch,
    t: usize,
    k: usize,
    contention: &Contention,
    round: &RoundInfo,
    mut ring: Option<&mut TraceRing>,
) {
    let n = act.len();
    let d = win.dim();
    let watch = ring.is_some();
    sc.jw.clear();
    sc.up_j.clear();
    sc.up_i.clear();
    sc.up_x.clear();
    sc.up_y.clear();
    sc.ops_before.clear();
    sc.ops_before.resize(n, 0);
    sc.resets_before.clear();
    sc.resets_before.resize(n, 0);

    // Pass 1: realize every active frame; batchable sessions defer their
    // feedback into the gather arrays (active order = gather order).
    for a in 0..n {
        let pos = act[a];
        let jw = sessions[pos - pos_base].slot - slot_base;
        sc.jw.push(jw);
        if !batchable[pos] {
            let mut slot = win.slot_mut(jw);
            session_realize(
                &mut sessions[pos - pos_base],
                Some(&mut slot),
                &decisions[a],
                &legs[a],
                t,
                k,
                contention,
                round,
                ring.as_deref_mut(),
            );
            continue;
        }
        if watch {
            sc.ops_before[a] = win.slot_at(jw).ops_since_refresh();
            sc.resets_before[a] = sessions[pos - pos_base].policy.reset_count();
        }
        let s = &mut sessions[pos - pos_base];
        let id = s.id;
        let Session { policy, env, metrics, front, contexts, expected, .. } = s;
        let (up_j, up_i, up_x, up_y) = (&mut sc.up_j, &mut sc.up_i, &mut sc.up_x, &mut sc.up_y);
        let mut sink = |x: &FeatureVector, y: f64| {
            up_j.push(jw);
            up_i.push(a);
            up_x.extend_from_slice(x);
            up_y.push(y);
        };
        realize_one(
            policy.as_mut(),
            None,
            env,
            metrics,
            front,
            contexts,
            expected,
            &decisions[a],
            t,
            k,
            contention,
            legs[a].0,
            legs[a].1,
            legs[a].2,
            round,
            id,
            Feedback::Defer(&mut sink),
        );
    }

    // Pass 2: drift prelude per observation against its pre-update slot
    // (exactly where the scalar observe checks).  Drift-consumed entries
    // re-learned inline; survivors compact in place for the batched
    // update.
    let mut w = 0;
    for i in 0..sc.up_j.len() {
        let jw = sc.up_j[i];
        let a = sc.up_i[i];
        let y = sc.up_y[i];
        let mut xv = [0.0f64; crate::models::CONTEXT_DIM];
        xv.copy_from_slice(&sc.up_x[i * d..(i + 1) * d]);
        let consumed = {
            let mut slot = win.slot_mut(jw);
            sessions[act[a] - pos_base]
                .policy
                .as_batched()
                .expect("batchable")
                .batch_observe_prelude(&mut slot, &xv, y)
        };
        if consumed {
            continue;
        }
        sc.up_j[w] = jw;
        sc.up_i[w] = a;
        sc.up_y[w] = y;
        sc.up_x.copy_within(i * d..(i + 1) * d, w * d);
        w += 1;
    }
    sc.up_j.truncate(w);
    sc.up_i.truncate(w);
    sc.up_y.truncate(w);
    sc.up_x.truncate(w * d);

    // Pass 3: one batched Sherman–Morrison update over the survivors.
    if !sc.up_j.is_empty() {
        win.update_batch_at(&sc.up_j, &sc.up_x, &sc.up_y);
    }

    // Pass 4: per-observation bookkeeping (counters, window history, θ̂
    // cache) against the post-update slot, in the same session order.
    for i in 0..sc.up_j.len() {
        let jw = sc.up_j[i];
        let a = sc.up_i[i];
        let mut xv = [0.0f64; crate::models::CONTEXT_DIM];
        xv.copy_from_slice(&sc.up_x[i * d..(i + 1) * d]);
        let slot = win.slot_mut(jw);
        sessions[act[a] - pos_base]
            .policy
            .as_batched()
            .expect("batchable")
            .batch_observe_commit(&slot, &xv, sc.up_y[i]);
    }

    // Pass 5: refresh/reset trace transitions for the deferred sessions
    // (the scalar path emits these inside `session_realize`; ring order
    // within a worker differs, but the canonical drain sort makes the
    // drained trace identical).
    if let Some(ring) = ring {
        for a in 0..n {
            let pos = act[a];
            if !batchable[pos] {
                continue;
            }
            let s = &sessions[pos - pos_base];
            let clock = round.capture_ms(t, s.id);
            let ops_after = win.slot_at(sc.jw[a]).ops_since_refresh();
            let resets_after = s.policy.reset_count();
            if ops_after < sc.ops_before[a] && resets_after == sc.resets_before[a] {
                ring.push(TraceEvent::new(
                    EventKind::PolicyRefresh,
                    t,
                    Some(s.id),
                    clock,
                    sc.ops_before[a] as f64,
                    0.0,
                ));
            }
            if resets_after > sc.resets_before[a] {
                ring.push(TraceEvent::new(
                    EventKind::PolicyReset,
                    t,
                    Some(s.id),
                    clock,
                    resets_after as f64,
                    0.0,
                ));
            }
        }
    }
}

/// Split `items` into `cuts.len() + 1` contiguous mutable parts at the
/// given ascending absolute cut positions: part 0 is `[0, cuts[0])`,
/// part `w` is `[cuts[w-1], cuts[w])`, the last part runs to the end.
fn split_positions<'a, T>(mut items: &'a mut [T], cuts: &[usize]) -> Vec<&'a mut [T]> {
    let mut parts = Vec::with_capacity(cuts.len() + 1);
    let mut base = 0;
    for &c in cuts {
        let (head, tail) = items.split_at_mut(c - base);
        parts.push(head);
        items = tail;
        base = c;
    }
    parts.push(items);
    parts
}

/// The per-shard tiling of one sharded phase: `act` (ascending list
/// positions of this round's active sessions) splits into `per`-entry
/// chunks, and the cut positions/slots anchor the congruent session
/// splits and variable-width store windows.  Balancing by **active**
/// count keeps workers evenly loaded however the idle residents are
/// laid out (DESIGN.md §14).
struct PhaseTiling {
    per: usize,
    /// Absolute list position where shard `w ≥ 1` begins (`act[w·per]`).
    pos_cuts: Vec<usize>,
    /// Absolute store slot where shard `w ≥ 1`'s window begins.
    slot_cuts: Vec<usize>,
}

impl PhaseTiling {
    fn new(sessions: &[Session], act: &[usize], workers: usize) -> PhaseTiling {
        let per = shard_len(act.len(), workers);
        let nshards = act.len().div_ceil(per);
        let pos_cuts: Vec<usize> = (1..nshards).map(|w| act[w * per]).collect();
        let slot_cuts: Vec<usize> = pos_cuts.iter().map(|&p| sessions[p].slot).collect();
        PhaseTiling { per, pos_cuts, slot_cuts }
    }

    /// `(pos_base, slot_base)` for shard `w`.
    fn base(&self, w: usize) -> (usize, usize) {
        if w == 0 {
            (0, 0)
        } else {
            (self.pos_cuts[w - 1], self.slot_cuts[w - 1])
        }
    }
}

/// Run the select phase across the round's active set, sharded over the
/// worker pool when one exists.  The phase is independent per session
/// (each owns its policy, environment RNG, and frame source; its learner
/// state lives at its `slot` in `store`), so any worker count yields
/// bit-identical decisions.
#[allow(clippy::too_many_arguments)]
fn select_phase(
    pool: Option<&WorkerPool>,
    sessions: &mut [Session],
    act: &[usize],
    store: &mut PolicyStore,
    decisions: &mut [Decision],
    batchable: &[bool],
    scratch: &mut [BatchScratch],
    batch: bool,
    t: usize,
    k_estimate: usize,
    contention: Contention,
    round: RoundInfo,
    timing: &mut [f64],
) {
    debug_assert_eq!(act.len(), decisions.len());
    debug_assert_eq!(sessions.len(), batchable.len());
    // Explicit empty no-op: a replica holding zero active sessions (or a
    // pool wider than the active set) must not rely on chunk-range
    // arithmetic producing nothing to iterate.
    if act.is_empty() {
        return;
    }
    let Some(pool) = pool else {
        let start = Instant::now();
        if batch {
            // One window over the whole store (pos/slot bases 0) — free
            // slots and idle residents inside it are never gathered.
            let mut win = store.as_slice_mut();
            select_shard_batched(
                sessions,
                0,
                act,
                0,
                decisions,
                &mut win,
                batchable,
                &mut scratch[0],
                t,
                k_estimate,
                &contention,
                &round,
            );
        } else {
            for (&pos, d) in act.iter().zip(decisions.iter_mut()) {
                let s = &mut sessions[pos];
                let mut slot = store.slot_mut(s.slot);
                *d = session_select(s, Some(&mut slot), t, k_estimate, &contention, &round);
            }
        }
        timing[0] += start.elapsed().as_secs_f64() * 1e3;
        return;
    };
    // Tile by active count: shard w owns act[w·per..(w+1)·per], the
    // session run and store window spanning exactly those entries.
    // Windows are disjoint borrows of the same arenas, no locks on the
    // arrays themselves (DESIGN.md §11/§14).  Each shard carries its
    // worker's phase timing slot; short pools leave trailing slots
    // untouched.
    let tiling = PhaseTiling::new(sessions, act, pool.workers());
    let windows = store.windows_at(&tiling.slot_cuts);
    let shards: Vec<_> = split_positions(sessions, &tiling.pos_cuts)
        .into_iter()
        .zip(act.chunks(tiling.per))
        .zip(decisions.chunks_mut(tiling.per))
        .zip(windows)
        .zip(scratch.iter_mut())
        .zip(timing.iter_mut())
        .enumerate()
        .map(|(w, (((((s, a), d), win), sc), tm))| {
            let (pos_base, slot_base) = tiling.base(w);
            Mutex::new((s, pos_base, a, slot_base, d, win, sc, tm))
        })
        .collect();
    pool.run(&|w| {
        if let Some(shard) = shards.get(w) {
            let start = Instant::now();
            let mut guard = shard.lock().expect("select shard lock");
            let (sessions, pos_base, act, slot_base, decisions, win, sc, tm) = &mut *guard;
            if batch {
                select_shard_batched(
                    &mut **sessions,
                    *pos_base,
                    act,
                    *slot_base,
                    &mut **decisions,
                    win,
                    batchable,
                    &mut **sc,
                    t,
                    k_estimate,
                    &contention,
                    &round,
                );
            } else {
                for (&pos, d) in act.iter().zip(decisions.iter_mut()) {
                    let s = &mut sessions[pos - *pos_base];
                    let mut slot = win.slot_mut(s.slot - *slot_base);
                    *d = session_select(s, Some(&mut slot), t, k_estimate, &contention, &round);
                }
            }
            **tm += start.elapsed().as_secs_f64() * 1e3;
        }
    });
}

/// Run the observe/realize phase across all sessions, sharded over the
/// worker pool when one exists.  All cross-session coupling (ingress
/// queueing, the edge scheduler) has already been resolved into `legs`
/// on the main thread, so this phase is again independent per session.
#[allow(clippy::too_many_arguments)]
fn observe_phase(
    pool: Option<&WorkerPool>,
    sessions: &mut [Session],
    act: &[usize],
    store: &mut PolicyStore,
    decisions: &[Decision],
    legs: &[Leg],
    batchable: &[bool],
    scratch: &mut [BatchScratch],
    batch: bool,
    t: usize,
    k: usize,
    contention: Contention,
    round: RoundInfo,
    timing: &mut [f64],
    rings: Option<&mut [TraceRing]>,
) {
    debug_assert_eq!(act.len(), decisions.len());
    debug_assert_eq!(act.len(), legs.len());
    debug_assert_eq!(sessions.len(), batchable.len());
    if act.is_empty() {
        return;
    }
    let Some(pool) = pool else {
        let start = Instant::now();
        let mut ring0 = rings.and_then(|r| r.first_mut());
        if batch {
            let mut win = store.as_slice_mut();
            observe_shard_batched(
                sessions,
                0,
                act,
                0,
                decisions,
                legs,
                &mut win,
                batchable,
                &mut scratch[0],
                t,
                k,
                &contention,
                &round,
                ring0,
            );
        } else {
            for ((&pos, d), leg) in act.iter().zip(decisions).zip(legs) {
                let s = &mut sessions[pos];
                let mut slot = store.slot_mut(s.slot);
                session_realize(
                    s,
                    Some(&mut slot),
                    d,
                    leg,
                    t,
                    k,
                    &contention,
                    &round,
                    ring0.as_deref_mut(),
                );
            }
        }
        timing[0] += start.elapsed().as_secs_f64() * 1e3;
        return;
    };
    // One trace ring per worker (all `None` when tracing is off).  This
    // per-phase Vec rides the existing pooled-mode O(W) shard-handle
    // allocation (see StepScratch docs) — the workers=1 inline path
    // above, which the zero-alloc audits pin, never builds it.
    let ring_opts: Vec<Option<&mut TraceRing>> = match rings {
        Some(rs) => rs.iter_mut().map(Some).collect(),
        None => (0..pool.workers()).map(|_| None).collect(),
    };
    let tiling = PhaseTiling::new(sessions, act, pool.workers());
    let windows = store.windows_at(&tiling.slot_cuts);
    let shards: Vec<_> = split_positions(sessions, &tiling.pos_cuts)
        .into_iter()
        .zip(act.chunks(tiling.per))
        .zip(decisions.chunks(tiling.per).zip(legs.chunks(tiling.per)))
        .zip(windows)
        .zip(scratch.iter_mut())
        .zip(ring_opts)
        .zip(timing.iter_mut())
        .enumerate()
        .map(|(w, ((((((s, a), (d, l)), win), sc), ring), tm))| {
            let (pos_base, slot_base) = tiling.base(w);
            Mutex::new((s, pos_base, a, slot_base, d, l, win, sc, ring, tm))
        })
        .collect();
    pool.run(&|w| {
        if let Some(shard) = shards.get(w) {
            let start = Instant::now();
            let mut guard = shard.lock().expect("observe shard lock");
            let (sessions, pos_base, act, slot_base, decisions, legs, win, sc, ring, tm) =
                &mut *guard;
            if batch {
                observe_shard_batched(
                    &mut **sessions,
                    *pos_base,
                    act,
                    *slot_base,
                    decisions,
                    legs,
                    win,
                    batchable,
                    &mut **sc,
                    t,
                    k,
                    &contention,
                    &round,
                    ring.as_deref_mut(),
                );
            } else {
                for ((&pos, d), leg) in act.iter().zip(decisions.iter()).zip(legs.iter()) {
                    let s = &mut sessions[pos - *pos_base];
                    let mut slot = win.slot_mut(s.slot - *slot_base);
                    session_realize(
                        s,
                        Some(&mut slot),
                        d,
                        leg,
                        t,
                        k,
                        &contention,
                        &round,
                        ring.as_deref_mut(),
                    );
                }
            }
            **tm += start.elapsed().as_secs_f64() * 1e3;
        }
    });
}

/// The multi-session serving engine (see module docs).
pub struct Engine {
    pub cfg: EngineConfig,
    /// Resident sessions, kept sorted by store slot between rounds
    /// ([`Engine::commit_membership`]).  In a closed fleet slots are
    /// handed out in id order, so this coincides with the historical
    /// id-sorted list; churn recycles freed slots, and slot order keeps
    /// phase iteration, shard tiling, and store windows congruent.
    sessions: Vec<Session>,
    /// Structure-of-arrays learner state (DESIGN.md §11): all ridge A
    /// matrices contiguous, all A⁻¹ contiguous, all b vectors
    /// contiguous.  Each resident session binds one slot
    /// (`Session::slot`); freed slots go on the store's free list and
    /// are recycled at the next admission, so the arenas never compact
    /// and surviving bindings stay valid across arbitrary churn.  On
    /// attach every policy moves its ridge state into its slot
    /// ([`Policy::adopt_slot`]); on detach ([`Engine::remove_session`])
    /// it takes the state back, so a migrating [`Session`] struct stays
    /// self-contained and cluster moves remain lossless.
    store: PolicyStore,
    /// `(global id, list position)` sorted by id — the O(log n) id
    /// lookup every cross-session mapping uses.  Stale while `dirty`.
    id_index: Vec<(usize, usize)>,
    /// Membership changed since the last [`Engine::commit_membership`]
    /// (session order, `batchable`, and `id_index` are stale).
    dirty: bool,
    /// Next global id handed out by [`Engine::add_session`] — ids are
    /// never recycled, so departed sessions stay addressable in traces.
    next_id: usize,
    ingress: Option<SharedIngress>,
    /// The event-driven edge server — `None` when the scheduler config
    /// degenerates to the PR 1 lockstep rounds.
    scheduler: Option<EdgeScheduler>,
    /// Persistent worker pool for the sharded phases — `None` when
    /// `cfg.workers <= 1` (every phase then runs inline).
    pool: Option<WorkerPool>,
    /// Reused per-round buffers (allocation-free steady state).
    scratch: StepScratch,
    /// Per-session batched-select eligibility, maintained at the same
    /// index as `sessions`/`store`: true iff the policy is a
    /// store-backed LinUCB ([`Policy::as_batched`]).  Drives
    /// [`SelectBatch::Auto`] and the per-session fallback inside the
    /// batched shard passes.
    batchable: Vec<bool>,
    /// Per-worker arm-major scratch arenas (DESIGN.md §13), pre-sized by
    /// [`Engine::reserve`] so the batched steady state never allocates.
    select_scratch: Vec<BatchScratch>,
    round: usize,
    /// Offload count of the previous round — the causal estimate every
    /// session selects under in the next round.
    offloaders_last: usize,
    /// k_t per completed round (diagnostics; drives the reported
    /// contention factors).
    offload_counts: Vec<usize>,
    /// Wall-clock time spent inside [`Engine::run`] (throughput
    /// reporting; never feeds back into any simulated quantity).
    serve_wall_ms: f64,
    /// Structured event tracer (`None` = tracing off; DESIGN.md §12).
    /// Rings are preallocated per shard so steady-state emission is a
    /// bounded store, never an allocation.
    tracer: Option<Tracer>,
    /// Wall-clock accounting per select/submit/realize/observe phase per
    /// worker.  Always on: recording is one `Instant` delta per phase,
    /// and wall readings never feed back into any simulated quantity.
    phases: PhaseClock,
    /// Trace events carried over from before a snapshot/restore:
    /// [`Engine::snapshot_state`] folds the live rings in here (so the
    /// snapshot holds the full history without consuming it) and
    /// [`Engine::restore_state`] seeds it from the snapshot, so
    /// [`Engine::drain_trace`] on a resumed engine returns the same
    /// canonical event stream an unbroken run would (DESIGN.md §15).
    trace_backlog: Vec<TraceEvent>,
    /// Ring-overflow drops recorded before the snapshot this engine was
    /// restored from (added to the live rings' counts).
    trace_dropped_carry: u64,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Engine {
        let ingress = cfg.ingress_mbps.map(SharedIngress::new);
        let scheduler = if cfg.scheduler.is_lockstep() {
            None
        } else {
            Some(EdgeScheduler::new(cfg.scheduler.clone(), cfg.contention))
        };
        let pool = if cfg.workers > 1 { Some(WorkerPool::new(cfg.workers)) } else { None };
        assert!(
            cfg.queue_signal.is_off() || scheduler.is_some(),
            "--queue-signal {} requires the event-driven edge scheduler \
             (enable --event-clock or a non-lockstep scheduler config)",
            cfg.queue_signal.name()
        );
        assert!(
            cfg.signal_stagger_ms >= 0.0 && cfg.signal_stagger_ms.is_finite(),
            "signal-stagger must be ≥ 0 ms"
        );
        assert!(
            cfg.signal_stagger_ms == 0.0 || !cfg.queue_signal.is_off(),
            "--signal-stagger perturbs the published queue signal and \
             requires --queue-signal wait|full"
        );
        let workers = cfg.workers.max(1);
        let tracer = if cfg.trace_capacity > 0 {
            Some(Tracer::new(workers, cfg.trace_capacity))
        } else {
            None
        };
        Engine {
            cfg,
            sessions: Vec::new(),
            store: PolicyStore::new(crate::models::CONTEXT_DIM),
            id_index: Vec::new(),
            dirty: false,
            next_id: 0,
            ingress,
            scheduler,
            pool,
            scratch: StepScratch::default(),
            batchable: Vec::new(),
            select_scratch: (0..workers).map(|_| BatchScratch::default()).collect(),
            round: 0,
            offloaders_last: 0,
            offload_counts: Vec::new(),
            serve_wall_ms: 0.0,
            tracer,
            phases: PhaseClock::new(workers),
            trace_backlog: Vec::new(),
            trace_dropped_carry: 0,
        }
    }

    /// Register a session; returns its (never-recycled) global id.
    pub fn add_session(
        &mut self,
        policy: Box<dyn Policy>,
        env: Environment,
        source: FrameSource,
    ) -> usize {
        let id = self.next_id;
        self.attach_session(Session::new(id, policy, env, source));
        id
    }

    /// Attach a fully-built session: allocate (or recycle) a store slot,
    /// move the incoming policy's owned ridge state into it (exact bits,
    /// including the Sherman–Morrison refresh phase), and defer the
    /// ordering work to [`Engine::commit_membership`] — O(1) amortized,
    /// so a burst of admissions costs one sort at the next round.
    pub fn attach_session(&mut self, mut session: Session) {
        debug_assert!(
            self.pos_of_id(session.id).is_none(),
            "duplicate session id {}",
            session.id
        );
        let slot = self.store.alloc_slot();
        let mut sm = self.store.slot_mut(slot);
        session.policy.adopt_slot(&mut sm);
        session.slot = slot;
        let id = session.id;
        self.next_id = self.next_id.max(id + 1);
        self.batchable.push(session.policy.as_batched().is_some());
        self.sessions.push(session);
        self.dirty = true;
        self.trace_membership(EventKind::SessionAttach, id);
    }

    /// Attach a fully-built session (cluster placement/migration) and
    /// commit membership immediately, so the engine's positional views
    /// are consistent before the next round.
    pub fn push_session(&mut self, session: Session) {
        self.attach_session(session);
        self.commit_membership();
    }

    /// Detach the session with the given global id (cluster migration).
    /// All per-session state — policy, environment RNG streams, frame
    /// source, metrics — moves wholesale with the struct, so the move
    /// itself is lossless (property-tested in `rust/tests/cluster.rs`).
    /// Only call at a round boundary: the edge queue holds no
    /// per-session references between rounds.
    pub fn remove_session(&mut self, id: usize) -> Session {
        let pos = self
            .pos_of_id(id)
            .unwrap_or_else(|| panic!("no session with id {id} in this engine"));
        let mut session = self.sessions.swap_remove(pos);
        self.batchable.swap_remove(pos);
        // Hand the ridge state back before freeing the slot: the departing
        // session is self-contained again (same bits, same refresh phase).
        session.policy.release_slot(self.store.slot(session.slot));
        self.store.free_slot(session.slot);
        session.slot = usize::MAX;
        session.active = true;
        self.dirty = true;
        self.commit_membership();
        self.trace_membership(EventKind::SessionEvict, id);
        session
    }

    /// Restore the between-rounds membership invariants after churn:
    /// sessions sorted by store slot, `batchable` re-derived per
    /// position, and the id index rebuilt.  Idempotent and allocation
    /// free once [`Engine::reserve_sessions`] has sized the structures —
    /// `sort_unstable` is O(n) on the nearly-sorted layouts churn
    /// produces, and [`Engine::step`] calls this once per dirty round.
    fn commit_membership(&mut self) {
        if !self.dirty {
            return;
        }
        self.sessions.sort_unstable_by_key(|s| s.slot);
        self.batchable.clear();
        for s in &mut self.sessions {
            self.batchable.push(s.policy.as_batched().is_some());
        }
        self.id_index.clear();
        self.id_index.extend(self.sessions.iter().enumerate().map(|(pos, s)| (s.id, pos)));
        self.id_index.sort_unstable_by_key(|&(id, _)| id);
        self.dirty = false;
    }

    /// List position of global id `id` — binary search through the id
    /// index when it is fresh, linear scan while membership edits are
    /// pending.
    fn pos_of_id(&self, id: usize) -> Option<usize> {
        if self.dirty {
            self.sessions.iter().position(|s| s.id == id)
        } else {
            self.id_index
                .binary_search_by_key(&id, |&(i, _)| i)
                .ok()
                .map(|k| self.id_index[k].1)
        }
    }

    /// Is a session with this global id resident (active or idle)?
    pub fn contains(&self, id: usize) -> bool {
        self.pos_of_id(id).is_some()
    }

    /// Borrow the resident session with this global id.
    pub fn session_by_id(&self, id: usize) -> Option<&Session> {
        self.pos_of_id(id).map(|pos| &self.sessions[pos])
    }

    /// Park (`false`) or resume (`true`) a resident session without
    /// detaching it: an idle resident keeps its store slot, environment
    /// clock, and every cursor exactly where they are, but is skipped by
    /// every phase until resumed — rounds cost O(active), not
    /// O(resident) (DESIGN.md §14).
    pub fn set_active(&mut self, id: usize, active: bool) {
        let pos = self
            .pos_of_id(id)
            .unwrap_or_else(|| panic!("no session with id {id} in this engine"));
        self.sessions[pos].active = active;
    }

    /// Resident sessions currently participating in rounds.
    pub fn num_active(&self) -> usize {
        self.sessions.iter().filter(|s| s.active).count()
    }

    /// Can the session with this id round-trip through the cold arena
    /// ([`Policy::supports_hibernate`])?
    pub fn can_hibernate(&self, id: usize) -> bool {
        self.pos_of_id(id)
            .is_some_and(|pos| self.sessions[pos].policy.supports_hibernate())
    }

    /// Hibernate a resident session at a round boundary: pack its policy
    /// cold state (ridge slot included), environment cursor, and
    /// frame-source cursor into `arena` (cleared first), free its store
    /// slot, and drop the [`Session`] — the session's resident cost
    /// becomes the arena bytes plus its metrics, nothing else
    /// (DESIGN.md §14).  Pass a recycled arena to keep churn rounds
    /// allocation-free.
    pub fn hibernate_session(&mut self, id: usize, mut arena: Vec<u8>) -> super::ColdSession {
        let pos = self
            .pos_of_id(id)
            .unwrap_or_else(|| panic!("no session with id {id} in this engine"));
        assert!(
            self.sessions[pos].policy.supports_hibernate(),
            "policy {} cannot hibernate",
            self.sessions[pos].policy.name()
        );
        let session = self.sessions.swap_remove(pos);
        self.batchable.swap_remove(pos);
        self.dirty = true;
        arena.clear();
        session.policy.pack_cold(Some(self.store.slot(session.slot)), &mut arena);
        session.env.pack_cursor(&mut arena);
        session.source.pack_cursor(&mut arena);
        self.store.free_slot(session.slot);
        self.trace_membership_b(EventKind::SessionHibernate, id, arena.len() as f64);
        super::ColdSession { id, arena, metrics: session.metrics }
    }

    /// Wake a hibernated session: bind a (recycled) store slot to the
    /// freshly-built `shell`, then overwrite policy, environment, and
    /// frame-source state from the cold arena — bit-identical to a twin
    /// that was never hibernated (pinned in `rust/tests/fleet.rs`).  The
    /// shell must be constructed from the same parameters as the
    /// original (wake rebinds structure; the arena restores state).
    /// Returns the arena for reuse.
    pub fn wake_session(&mut self, cold: super::ColdSession, mut shell: Session) -> Vec<u8> {
        let super::ColdSession { id, arena, metrics } = cold;
        debug_assert_eq!(shell.id, id, "wake shell must match the cold session's id");
        shell.metrics = metrics;
        let slot = self.store.alloc_slot();
        {
            let mut sm = self.store.slot_mut(slot);
            shell.policy.adopt_slot(&mut sm);
        }
        shell.slot = slot;
        {
            let mut r = crate::util::bytes::Reader::new(&arena);
            let mut sm = self.store.slot_mut(slot);
            shell.policy.unpack_cold(Some(&mut sm), &mut r);
            shell.env.unpack_cursor(&mut r);
            shell.source.unpack_cursor(&mut r);
            assert!(r.is_empty(), "cold arena not fully consumed on wake (session {id})");
        }
        self.batchable.push(shell.policy.as_batched().is_some());
        self.next_id = self.next_id.max(id + 1);
        self.sessions.push(shell);
        self.dirty = true;
        self.trace_membership_b(EventKind::SessionWake, id, arena.len() as f64);
        arena
    }

    /// Permanently remove a resident session at a round boundary,
    /// discarding learner and environment state but returning its
    /// metrics so its served records survive for reporting.
    pub fn evict_session(&mut self, id: usize) -> Metrics {
        let pos = self
            .pos_of_id(id)
            .unwrap_or_else(|| panic!("no session with id {id} in this engine"));
        let session = self.sessions.swap_remove(pos);
        self.batchable.swap_remove(pos);
        self.store.free_slot(session.slot);
        self.dirty = true;
        self.trace_membership(EventKind::SessionEvict, id);
        session.metrics
    }

    /// Pre-size the membership structures (and the store's slot arenas +
    /// free list) for `extra` more resident sessions, so admissions,
    /// hibernations, and wakes within that envelope never allocate
    /// inside a churn round.
    pub fn reserve_sessions(&mut self, extra: usize) {
        self.sessions.reserve(extra);
        self.batchable.reserve(extra);
        let want = self.sessions.len() + extra;
        self.id_index.reserve(want.saturating_sub(self.id_index.len()));
        self.store.reserve_slots(extra);
        // Pre-size every per-round buffer to the residency envelope so a
        // churn round (admission + hibernation + active-set growth) stays
        // allocation-free — the hotpath bench's churn audit.
        self.scratch.reserve(want);
        if want > 0 {
            let per = shard_len(want, self.cfg.workers.max(1));
            let d = self.store.dim();
            let arms =
                self.sessions.iter().map(|s| s.env.num_partitions() + 1).max().unwrap_or(0);
            for sc in &mut self.select_scratch {
                sc.reserve(per, d, arms);
            }
        }
    }

    /// Emit a membership trace event (attach/evict), stamped at the
    /// current round boundary on the virtual clock with the resident
    /// count after the change.
    fn trace_membership(&mut self, kind: EventKind, id: usize) {
        self.trace_membership_b(kind, id, 0.0);
    }

    /// [`Engine::trace_membership`] with a payload in the `b` field
    /// (hibernate/wake carry the cold-arena byte count).
    fn trace_membership_b(&mut self, kind: EventKind, id: usize, b: f64) {
        if let Some(tr) = self.tracer.as_mut() {
            let clock = self.round as f64 * self.cfg.frame_interval_ms;
            let n = self.sessions.len() as f64;
            tr.main().push(TraceEvent::new(kind, self.round, Some(id), clock, n, b));
        }
    }

    /// Record a cluster migration in this (destination) engine's trace:
    /// `a` = source replica, `b` = destination replica.  The cluster
    /// router calls this right after [`Engine::push_session`].
    pub fn trace_migrate(&mut self, id: usize, from: usize, to: usize) {
        if let Some(tr) = self.tracer.as_mut() {
            let clock = self.round as f64 * self.cfg.frame_interval_ms;
            tr.main().push(TraceEvent::new(
                EventKind::SessionMigrate,
                self.round,
                Some(id),
                clock,
                from as f64,
                to as f64,
            ));
        }
    }

    /// Is structured tracing active on this engine?
    pub fn trace_enabled(&self) -> bool {
        self.tracer.is_some()
    }

    /// Stamp every event this engine traces with a replica id (cluster
    /// replicas; standalone engines leave events unstamped).
    pub fn set_trace_replica(&mut self, replica: usize) {
        if let Some(tr) = self.tracer.as_mut() {
            tr.set_replica(replica);
        }
    }

    /// Drain the trace rings into the canonical event sequence (sorted
    /// by round, kind, session — see [`Tracer::drain`]).  Empty when
    /// tracing is off.  Report-time only: draining allocates.  Any
    /// snapshot/restore backlog is merged in front, so a resumed run's
    /// trace is the unbroken run's trace.
    pub fn drain_trace(&mut self) -> Vec<TraceEvent> {
        let mut out = std::mem::take(&mut self.trace_backlog);
        if let Some(tr) = self.tracer.as_mut() {
            if out.is_empty() {
                return tr.drain();
            }
            out.extend(tr.drain());
            out.sort_by_key(|e| (e.round, e.kind, e.session));
        }
        out
    }

    /// Events overwritten because a trace ring was full (0 = the trace
    /// is complete).  Includes drops recorded before the snapshot a
    /// resumed engine was restored from.
    pub fn trace_dropped(&self) -> u64 {
        self.trace_dropped_carry + self.tracer.as_ref().map_or(0, Tracer::dropped)
    }

    /// Accumulated wall-clock per select/submit/realize/observe phase
    /// per worker (always on).
    pub fn phase_clock(&self) -> &PhaseClock {
        &self.phases
    }

    // --- Typed snapshot / restore (DESIGN.md §15) ----------------------

    /// Name of the first resident policy that cannot round-trip through
    /// a cold arena (`None` = the whole engine can be snapshotted).
    /// The CLI checks this before `--snapshot`/`--distribute process`
    /// and turns an unsupported policy (Neurosurgeon) into a friendly
    /// error instead of a panic.
    pub fn unsnapshottable_policy(&self) -> Option<String> {
        self.sessions
            .iter()
            .find(|s| !s.policy.supports_hibernate())
            .map(|s| s.policy.name().to_string())
    }

    /// Capture the engine's complete mutable serving state as a typed
    /// [`super::snapshot::EngineState`].  Non-destructive: the engine
    /// keeps running afterwards, bit-identical to a twin that was never
    /// snapshotted (the cold-arena pack is `&self`; the one side effect
    /// is folding the live trace rings into the retained backlog, which
    /// [`Engine::drain_trace`] returns either way).  Call at a round
    /// boundary only — between rounds the edge queue's waiting room and
    /// virtual clocks are the entire scheduler state, so packing them
    /// captures everything in flight.
    pub fn snapshot_state(&mut self) -> super::snapshot::EngineState {
        use crate::util::bytes::put_usize;
        self.commit_membership();
        // Fold the live rings into the backlog: the snapshot carries the
        // full event history and the engine keeps it for its own drain.
        if let Some(tr) = self.tracer.as_mut() {
            let fresh = tr.drain();
            if !fresh.is_empty() {
                self.trace_backlog.extend(fresh);
                self.trace_backlog.sort_by_key(|e| (e.round, e.kind, e.session));
            }
        }
        let mut sessions = Vec::with_capacity(self.sessions.len());
        for s in &self.sessions {
            assert!(
                s.policy.supports_hibernate(),
                "policy {} cannot snapshot (no cold round-trip); \
                 check Engine::unsnapshottable_policy first",
                s.policy.name()
            );
            let mut arena = Vec::new();
            s.policy.pack_cold(Some(self.store.slot(s.slot)), &mut arena);
            s.env.pack_cursor(&mut arena);
            s.source.pack_cursor(&mut arena);
            let mut records = Vec::new();
            s.metrics.pack(&mut records);
            sessions.push(super::snapshot::SessionState {
                id: s.id,
                active: s.active,
                slot: s.slot,
                arena,
                records,
            });
        }
        let mut ingress = Vec::new();
        if let Some(ing) = self.ingress.as_ref() {
            ing.pack_state(&mut ingress);
        }
        let mut scheduler = Vec::new();
        if let Some(sched) = self.scheduler.as_ref() {
            sched.pack_state(&mut scheduler);
        }
        let mut trace = Vec::new();
        put_usize(&mut trace, self.trace_backlog.len());
        for e in &self.trace_backlog {
            e.pack(&mut trace);
        }
        super::snapshot::EngineState {
            round: self.round,
            next_id: self.next_id,
            offloaders_last: self.offloaders_last,
            offload_counts: self.offload_counts.clone(),
            store_slots: self.store.len(),
            free_slots: self.store.free_list().to_vec(),
            ingress,
            scheduler,
            sessions,
            trace,
            trace_dropped: self.trace_dropped(),
        }
    }

    /// Rebuild a snapshotted engine into `self`, which must be a
    /// freshly-built engine with the same [`EngineConfig`].  `shells`
    /// holds one config-identical [`Session`] shell per snapshot
    /// session, in snapshot order and built from the same parameters as
    /// the originals — restore rebinds structure, the snapshot overlays
    /// state (the [`Engine::wake_session`] contract, generalized to the
    /// whole engine).  Restore is trace-silent: membership is rebuilt by
    /// direct field surgery rather than [`Engine::attach_session`], so
    /// no spurious attach events pollute the resumed trace (the packed
    /// backlog already holds the history).  The result is bit-identical
    /// to the engine that was snapshotted, pinned on disk in
    /// `rust/tests/snapshot.rs`.
    pub fn restore_state(&mut self, state: &super::snapshot::EngineState, shells: Vec<Session>) {
        use crate::util::bytes::Reader;
        assert!(
            self.sessions.is_empty() && self.round == 0,
            "restore_state needs a fresh engine"
        );
        assert_eq!(
            shells.len(),
            state.sessions.len(),
            "restore needs one shell per snapshot session"
        );
        // Rebuild the store's slot window exactly: push every slot in
        // index order, then free the snapshot's free list.  free_slot
        // keeps the list sorted descending, so the rebuilt vector is
        // identical to the snapshot's regardless of replay order.
        for _ in 0..state.store_slots {
            self.store.push_slot();
        }
        for &f in &state.free_slots {
            self.store.free_slot(f);
        }
        for (mut shell, ss) in shells.into_iter().zip(&state.sessions) {
            assert_eq!(shell.id, ss.id, "shell order must match snapshot order");
            assert!(ss.slot < state.store_slots, "session {} slot {} out of window", ss.id, ss.slot);
            {
                let mut sm = self.store.slot_mut(ss.slot);
                shell.policy.adopt_slot(&mut sm);
            }
            shell.slot = ss.slot;
            shell.active = ss.active;
            {
                let mut r = Reader::new(&ss.arena);
                let mut sm = self.store.slot_mut(ss.slot);
                shell.policy.unpack_cold(Some(&mut sm), &mut r);
                shell.env.unpack_cursor(&mut r);
                shell.source.unpack_cursor(&mut r);
                assert!(
                    r.is_empty(),
                    "snapshot arena not fully consumed (session {})",
                    ss.id
                );
            }
            {
                let mut r = Reader::new(&ss.records);
                shell.metrics = Metrics::unpack(&mut r);
                assert!(
                    r.is_empty(),
                    "snapshot records not fully consumed (session {})",
                    ss.id
                );
            }
            self.batchable.push(shell.policy.as_batched().is_some());
            self.sessions.push(shell);
        }
        self.dirty = true;
        self.commit_membership();
        self.next_id = state.next_id;
        self.round = state.round;
        self.offloaders_last = state.offloaders_last;
        self.offload_counts = state.offload_counts.clone();
        match self.ingress.as_mut() {
            Some(ing) => {
                let mut r = Reader::new(&state.ingress);
                ing.unpack_state(&mut r);
                assert!(r.is_empty(), "snapshot ingress state not fully consumed");
            }
            None => assert!(
                state.ingress.is_empty(),
                "snapshot carries shared-ingress state but this engine has none \
                 (config mismatch)"
            ),
        }
        match self.scheduler.as_mut() {
            Some(sched) => {
                let mut r = Reader::new(&state.scheduler);
                sched.unpack_state(&mut r);
                assert!(r.is_empty(), "snapshot scheduler state not fully consumed");
            }
            None => assert!(
                state.scheduler.is_empty(),
                "snapshot carries edge-scheduler state but this engine runs \
                 lockstep (config mismatch)"
            ),
        }
        {
            let mut r = Reader::new(&state.trace);
            let n = r.take_usize();
            self.trace_backlog = (0..n).map(|_| TraceEvent::unpack(&mut r)).collect();
            assert!(r.is_empty(), "snapshot trace backlog not fully consumed");
        }
        self.trace_dropped_carry = state.trace_dropped;
    }

    /// The deterministic pre-round queue forecast ([`EdgeEstimate`]) —
    /// idle when the engine runs the lockstep path.  The cluster router
    /// freezes this per replica before placement decisions.
    pub fn forecast(&self) -> EdgeEstimate {
        match self.scheduler.as_ref() {
            Some(s) => s.forecast(),
            None => EdgeEstimate::idle(),
        }
    }

    /// Does the next round run the arm-major batched select/observe?
    /// Resolves [`SelectBatch::Auto`] against the resident fleet.
    fn batch_active(&self) -> bool {
        match self.cfg.select_batch {
            SelectBatch::Off => false,
            SelectBatch::On => true,
            SelectBatch::Auto => {
                !self.sessions.is_empty() && self.batchable.iter().all(|&b| b)
            }
        }
    }

    /// The select mode the engine actually runs ("on"/"off") after
    /// resolving [`SelectBatch::Auto`] — recorded in
    /// [`FleetSummary::select_batch`] so bench JSONs are self-describing.
    pub fn select_batch_effective(&self) -> &'static str {
        if self.batch_active() {
            "on"
        } else {
            "off"
        }
    }

    pub fn num_sessions(&self) -> usize {
        self.sessions.len()
    }

    pub fn sessions(&self) -> &[Session] {
        &self.sessions
    }

    pub fn sessions_mut(&mut self) -> &mut [Session] {
        &mut self.sessions
    }

    pub fn into_sessions(mut self) -> Vec<Session> {
        for s in self.sessions.iter_mut() {
            s.policy.release_slot(self.store.slot(s.slot));
            s.slot = usize::MAX;
        }
        // Canonical hand-off order (report-time only).
        self.sessions.sort_unstable_by_key(|s| s.id);
        self.sessions
    }

    /// Diagnostics snapshot of the session at local index `idx`, read
    /// through its store slot (works for store-backed and owned policies
    /// alike — the slot is simply ignored by the latter).
    pub fn policy_snapshot(&self, idx: usize) -> PolicySnapshot {
        let s = &self.sessions[idx];
        s.policy.snapshot_in(Some(self.store.slot(s.slot)))
    }

    /// [`Engine::policy_snapshot`] addressed by *global* session id.
    pub fn policy_snapshot_by_id(&self, id: usize) -> PolicySnapshot {
        let idx = self
            .pos_of_id(id)
            .unwrap_or_else(|| panic!("no session with id {id} in this engine"));
        self.policy_snapshot(idx)
    }

    /// One diagnostics snapshot per resident session, in id order.
    pub fn policy_snapshots(&self) -> Vec<PolicySnapshot> {
        (0..self.sessions.len()).map(|i| self.policy_snapshot(i)).collect()
    }

    /// Rounds completed so far.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Concurrent offload count k_t per completed round.
    pub fn offload_counts(&self) -> &[usize] {
        &self.offload_counts
    }

    /// The event-driven edge queue's cumulative diagnostics (None on the
    /// lockstep path, where the per-record stats are the whole story).
    pub fn scheduler_stats(&self) -> Option<&QueueStats> {
        self.scheduler.as_ref().map(|s| s.stats())
    }

    /// The frozen cross-session inputs of the next round: the queue
    /// forecast is taken *before* any of the round's offloads submit
    /// (the select-phase snapshot), on the main thread, so it is
    /// identical at every worker count.
    fn round_info(&self) -> RoundInfo {
        RoundInfo {
            estimate: self.forecast(),
            signal: self.cfg.queue_signal,
            frame_interval_ms: self.cfg.frame_interval_ms,
            stagger_ms: self.cfg.scheduler.stagger_ms,
            signal_stagger_ms: self.cfg.signal_stagger_ms,
            deadline_ms: self.cfg.scheduler.deadline_ms,
            event: self.scheduler.is_some(),
        }
    }

    /// Serve one frame for every session (one engine round).  An engine
    /// holding zero sessions (an idle cluster replica between
    /// migrations) is a deterministic no-op round: the virtual clock and
    /// queue state stay put, k_t = 0 is logged, and the round counter
    /// advances so replicas stay aligned.
    pub fn step(&mut self) {
        let step_start = Instant::now();
        self.commit_membership();
        let mut scratch = std::mem::take(&mut self.scratch);
        // The round's active-set index: ascending list positions (==
        // ascending slots) of the sessions that participate.  O(resident)
        // to rebuild; every phase below is O(active).
        scratch.act.clear();
        scratch
            .act
            .extend(self.sessions.iter().enumerate().filter(|(_, s)| s.active).map(|(i, _)| i));
        if scratch.act.is_empty() {
            // No active sessions (an empty engine, or an all-idle
            // resident fleet): a deterministic no-op round — the virtual
            // clock and queue state stay put, k_t = 0 is logged, and the
            // round counter advances so replicas stay aligned.
            self.scratch = scratch;
            self.offloaders_last = 0;
            self.offload_counts.push(0);
            self.push_round_barrier(self.round, 0, step_start);
            self.round += 1;
            return;
        }
        let t = self.round;
        let k_estimate = self.offloaders_last;
        let contention = self.cfg.contention;
        let n = scratch.act.len();
        let round = self.round_info();
        if round.event {
            // Trace the frozen pre-round forecast every policy selects
            // under (clock = when the executor frees up).
            if let Some(tr) = self.tracer.as_mut() {
                tr.main().push(TraceEvent::new(
                    EventKind::ForecastFrozen,
                    t,
                    None,
                    round.estimate.free_at_ms,
                    round.estimate.backlog as f64,
                    round.estimate.merge_probability,
                ));
            }
        }

        // Phase 1 (sharded): every active session picks a partition under
        // last round's observed concurrency (the causal load estimate) —
        // or, under a queue signal, the pre-round queue forecast.
        scratch.decisions.clear();
        scratch.decisions.resize(
            n,
            Decision { p: 0, is_key: false, weight: 0.0, predicted_edge_ms: None },
        );
        let batch = self.batch_active();
        select_phase(
            self.pool.as_ref(),
            &mut self.sessions,
            &scratch.act,
            &mut self.store,
            &mut scratch.decisions,
            &self.batchable,
            &mut self.select_scratch,
            batch,
            t,
            k_estimate,
            contention,
            round,
            self.phases.row_mut(Phase::Select),
        );

        // Phase 2: the actual concurrency this round determines the edge
        // load everyone realizes.
        let k = scratch
            .decisions
            .iter()
            .zip(scratch.act.iter())
            .filter(|(d, &pos)| d.p != self.sessions[pos].env.num_partitions())
            .count();

        if self.scheduler.is_none() {
            self.realize_lockstep(t, k, &mut scratch, round);
        } else {
            self.realize_event(t, k, &mut scratch, round);
        }
        self.scratch = scratch;

        self.offloaders_last = k;
        self.offload_counts.push(k);
        self.push_round_barrier(t, k, step_start);
        self.round += 1;
    }

    /// Trace the end-of-round barrier: `a` = k_t, `wall_ms` = wall time
    /// the round took (the only nondeterministic trace field — the
    /// worker-count pins compare events through
    /// [`TraceEvent::sans_wall`]).
    fn push_round_barrier(&mut self, t: usize, k: usize, step_start: Instant) {
        if let Some(tr) = self.tracer.as_mut() {
            let clock = (t + 1) as f64 * self.cfg.frame_interval_ms;
            let mut ev = TraceEvent::new(EventKind::RoundBarrier, t, None, clock, k as f64, 0.0);
            ev.wall_ms = step_start.elapsed().as_secs_f64() * 1e3;
            tr.main().push(ev);
        }
    }

    /// PR 1's lockstep realize phase, byte for byte: factor(k_t) on every
    /// environment, the arrival-ordered shared-ingress pass, then one
    /// noisy draw per session — sharded across the pool, which preserves
    /// the per-session draw order exactly (each session's RNG is its
    /// own), so the result is identical at any worker count.
    fn realize_lockstep(
        &mut self,
        t: usize,
        k: usize,
        scratch: &mut StepScratch,
        round: RoundInfo,
    ) {
        let contention = self.cfg.contention;
        let now_ms = t as f64 * self.cfg.frame_interval_ms;
        let StepScratch { act, decisions, legs, arrivals, .. } = scratch;
        let n = act.len();
        legs.clear();
        legs.resize(n, (0.0, 1, EdgeLeg::Lockstep));

        // Trace every offload submission (tracer-gated: recomputing
        // bytes/tx here keeps the hot loop below untouched when off).
        if let Some(tr) = self.tracer.as_mut() {
            let ring = tr.main();
            for (&pos, d) in act.iter().zip(decisions.iter()) {
                let s = &self.sessions[pos];
                if d.p == s.env.num_partitions() {
                    continue;
                }
                let bytes = s.env.psi_bytes(d.p);
                let tx = crate::simulator::tx_delay_ms(
                    bytes,
                    s.env.current_rate_mbps(),
                    s.env.rtt_ms,
                );
                ring.push(TraceEvent::new(
                    EventKind::FrameSubmitted,
                    t,
                    Some(s.id),
                    now_ms + s.front[d.p] + tx,
                    d.p as f64,
                    bytes as f64,
                ));
            }
        }
        let realize_start = Instant::now();

        // Shared-ingress pass, in *physical arrival order* (FIFO at the
        // edge NIC, independent of session index): each ψ_p arrives once
        // its front finished AND its bytes crossed the session's own
        // uplink (expected tx time; the noisy realization is drawn in
        // realize_one on top of this queueing term).  The merge order is
        // canonical — arrival time, ties by **global session id** — so
        // neither the worker count nor the residency layout perturbs it.
        if let Some(ingress) = &mut self.ingress {
            for (a, (&pos, d)) in act.iter().zip(decisions.iter()).enumerate() {
                let s = &self.sessions[pos];
                if d.p == s.env.num_partitions() {
                    continue;
                }
                let bytes = s.env.psi_bytes(d.p);
                let tx = crate::simulator::tx_delay_ms(
                    bytes,
                    s.env.current_rate_mbps(),
                    s.env.rtt_ms,
                );
                arrivals.push_keyed(now_ms + s.front[d.p] + tx, s.id as u64, (a, bytes));
            }
            while let Some((arrival_ms, (a, bytes))) = arrivals.pop() {
                legs[a].0 = ingress.consume(bytes, arrival_ms);
            }
        }
        self.phases.add(Phase::Realize, 0, realize_start.elapsed().as_secs_f64() * 1e3);

        let batch = self.batch_active();
        observe_phase(
            self.pool.as_ref(),
            &mut self.sessions,
            &scratch.act,
            &mut self.store,
            &scratch.decisions,
            &scratch.legs,
            &self.batchable,
            &mut self.select_scratch,
            batch,
            t,
            k,
            contention,
            round,
            self.phases.row_mut(Phase::Observe),
            self.tracer.as_mut().map(|tr| tr.worker_rings()),
        );
    }

    /// Event-driven realize phase: offloads become [`EdgeJob`]s on the
    /// fleet's virtual clock (capture + front + uplink + ingress),
    /// admission rejects what the waiting room cannot hold (those frames
    /// finish on-device), and the queue resolves waits/batches whose
    /// delays — not a multiplicative factor — are the contention the
    /// bandits observe.  Executor backlog persists across rounds, so
    /// offloads contend when they overlap in *time*, not round index.
    ///
    /// All shared state (ingress, waiting room, virtual clock) is
    /// resolved here on the main thread in canonical (arrival time,
    /// session id) merge order; only the final per-session noisy draw +
    /// learn + record step fans out across the pool.
    fn realize_event(&mut self, t: usize, k: usize, scratch: &mut StepScratch, round: RoundInfo) {
        let contention = self.cfg.contention;
        let batch = self.batch_active();
        let Engine {
            sessions,
            store,
            id_index,
            ingress,
            scheduler,
            pool,
            tracer,
            phases,
            batchable,
            select_scratch,
            ..
        } = self;
        let scheduler = scheduler.as_mut().expect("event path has a scheduler");
        let deadline = scheduler.cfg.deadline_ms;
        // Main-thread event ring for the shared-state resolution below
        // (everything here runs in canonical merge order regardless of
        // the worker count, so the trace is worker-count invariant).
        let mut ring = tracer.as_mut().map(|tr| tr.main());
        let submit_start = Instant::now();

        let StepScratch {
            act,
            decisions,
            arrivals,
            legs,
            tx_ms,
            ingress_wait,
            rejected,
            outcomes,
            scheduled,
        } = scratch;
        let n = act.len();
        tx_ms.clear();
        tx_ms.resize(n, 0.0);
        ingress_wait.clear();
        ingress_wait.resize(n, 0.0);
        rejected.clear();
        rejected.resize(n, false);
        outcomes.clear();
        outcomes.resize(n, None);

        // NIC arrivals in physical order (same canonical merge as the
        // lockstep ingress pass: arrival time, ties by global session
        // id).
        for (a, (&pos, d)) in act.iter().zip(decisions.iter()).enumerate() {
            let s = &sessions[pos];
            if d.p == s.env.num_partitions() {
                continue;
            }
            let bytes = s.env.psi_bytes(d.p);
            let tx =
                crate::simulator::tx_delay_ms(bytes, s.env.current_rate_mbps(), s.env.rtt_ms);
            // Capture staggering keys on the *global* session id (== the
            // local index in a standalone closed engine, but not in a
            // cluster replica or a churned fleet, where ids outlive
            // residency layouts).
            let capture = round.capture_ms(t, s.id);
            tx_ms[a] = tx;
            if let Some(r) = ring.as_deref_mut() {
                r.push(TraceEvent::new(
                    EventKind::FrameSubmitted,
                    t,
                    Some(s.id),
                    capture + s.front[d.p] + tx,
                    d.p as f64,
                    bytes as f64,
                ));
            }
            arrivals.push_keyed(capture + s.front[d.p] + tx, s.id as u64, (a, bytes));
        }

        // Admission (before the payload spends shared-ingress bandwidth),
        // then ingress, then the waiting room.
        while let Some((nic_ms, (a, bytes))) = arrivals.pop() {
            let i = act[a];
            if !scheduler.has_room() {
                scheduler.note_rejected();
                rejected[a] = true;
                if let Some(r) = ring.as_deref_mut() {
                    r.push(TraceEvent::new(
                        EventKind::FrameRejected,
                        t,
                        Some(sessions[i].id),
                        nic_ms,
                        decisions[a].p as f64,
                        0.0,
                    ));
                }
                continue;
            }
            let ing = match ingress.as_mut() {
                Some(g) => g.consume(bytes, nic_ms),
                None => 0.0,
            };
            ingress_wait[a] = ing;
            let d = &decisions[a];
            if let Some(r) = ring.as_deref_mut() {
                r.push(TraceEvent::new(
                    EventKind::FrameAdmitted,
                    t,
                    Some(sessions[i].id),
                    nic_ms + ing,
                    d.p as f64,
                    ing,
                ));
            }
            let capture = round.capture_ms(t, sessions[i].id);
            // Jobs carry the GLOBAL session id so the queue's cross-round
            // per-session state (WeightedFair credit) is never
            // misattributed after a cluster migration: a departing
            // session's credit is parked under its own id (and restored
            // if it returns to this replica) instead of silently
            // accruing to whichever session occupies the same local slot
            // next round.  Credit does NOT transfer between replicas — a
            // migrant starts from zero on its new queue (DESIGN.md §10).
            // In a standalone engine id == local index, so nothing
            // changes.
            let submitted = scheduler.submit(EdgeJob {
                session: sessions[i].id,
                p: d.p,
                bytes,
                capture_ms: capture,
                arrival_ms: nic_ms + ing,
                deadline_ms: if deadline.is_finite() {
                    capture + deadline
                } else {
                    f64::INFINITY
                },
                weight: d.weight,
                solo_ms: sessions[i].env.solo_backend_ms(d.p),
                seq: 0,
            });
            debug_assert!(submitted, "has_room was checked");
        }

        phases.add(Phase::Submit, 0, submit_start.elapsed().as_secs_f64() * 1e3);
        let realize_start = Instant::now();

        scheduler.drain_scheduled_into(scheduled);
        for sch in scheduled.iter() {
            // Map the job's global session id back through the id index
            // to its list position, then to its active-set entry — both
            // exact, allocation-free lookups.
            let pos = id_index
                [id_index
                    .binary_search_by_key(&sch.session, |&(id, _)| id)
                    .expect("scheduled job belongs to a resident session")]
            .1;
            let a = act
                .binary_search(&pos)
                .expect("scheduled job belongs to an active session");
            outcomes[a] = Some(Outcome::Served {
                queue_wait_ms: sch.queue_wait_ms,
                service_ms: sch.service_ms,
                batch_size: sch.batch_size,
            });
            if let Some(r) = ring.as_deref_mut() {
                r.push(TraceEvent::new(
                    EventKind::FrameBatched,
                    t,
                    Some(sch.session),
                    sch.start_ms,
                    sch.batch_size as f64,
                    sch.queue_wait_ms,
                ));
            }
        }
        if !scheduled.is_empty() {
            if let Some(r) = ring.as_deref_mut() {
                r.push(TraceEvent::new(
                    EventKind::QueueDrain,
                    t,
                    None,
                    scheduler.free_at_ms(),
                    scheduled.len() as f64,
                    scheduler.pending() as f64,
                ));
            }
        }

        // Per-session leg resolution (cheap, read-only), then the
        // sharded observe phase: each session's noise stream draws
        // deterministically, exactly one draw per offload attempt.
        legs.clear();
        for (a, (&pos, d)) in act.iter().zip(decisions.iter()).enumerate() {
            let s = &sessions[pos];
            let p = d.p;
            let leg = if p == s.env.num_partitions() {
                (0.0, 1, EdgeLeg::Lockstep)
            } else if rejected[a] {
                let mean = tx_ms[a] + s.env.device_fallback_ms(p);
                if let Some(r) = ring.as_deref_mut() {
                    r.push(TraceEvent::new(
                        EventKind::DeviceFallback,
                        t,
                        Some(s.id),
                        round.capture_ms(t, s.id),
                        p as f64,
                        mean,
                    ));
                }
                (0.0, 0, EdgeLeg::Event { mean_ms: mean, rejected: true })
            } else {
                match outcomes[a] {
                    Some(Outcome::Served { queue_wait_ms, service_ms, batch_size }) => {
                        let qw = ingress_wait[a] + queue_wait_ms;
                        let mean = tx_ms[a] + qw + service_ms;
                        (qw, batch_size, EdgeLeg::Event { mean_ms: mean, rejected: false })
                    }
                    _ => unreachable!("every admitted offload is scheduled"),
                }
            };
            legs.push(leg);
        }
        drop(ring);
        phases.add(Phase::Realize, 0, realize_start.elapsed().as_secs_f64() * 1e3);

        observe_phase(
            pool.as_ref(),
            sessions,
            act,
            store,
            decisions,
            legs,
            batchable,
            select_scratch,
            batch,
            t,
            k,
            contention,
            round,
            phases.row_mut(Phase::Observe),
            tracer.as_mut().map(|tr| tr.worker_rings()),
        );
    }

    /// Pre-size every per-session record buffer (and the k_t log) for
    /// `rounds` more rounds, so steady-state serving never reallocates
    /// on the hot path.  [`Engine::run`] calls this automatically.
    pub fn reserve(&mut self, rounds: usize) {
        for s in &mut self.sessions {
            s.metrics.reserve(rounds);
        }
        self.offload_counts.reserve(rounds);
        // Pre-size the arm-major scratch arenas so the batched phases
        // never allocate in steady state (the hotpath bench's
        // `alloc/engine_armmajor_steady_state` audit).  Windowed-policy
        // eviction gathers can still grow past `per` entries in a burst;
        // the standard fleet (μLinUCB, no window) never does.
        let n = self.sessions.len();
        if n > 0 {
            let per = shard_len(n, self.cfg.workers.max(1));
            let d = self.store.dim();
            let arms =
                self.sessions.iter().map(|s| s.env.num_partitions() + 1).max().unwrap_or(0);
            for sc in &mut self.select_scratch {
                sc.reserve(per, d, arms);
            }
        }
    }

    /// Serve `rounds` frames per session, accumulating wall-clock time
    /// for throughput reporting ([`FleetSummary::frames_per_sec`]).
    pub fn run(&mut self, rounds: usize) {
        self.reserve(rounds);
        let start = Instant::now();
        for _ in 0..rounds {
            self.step();
        }
        self.serve_wall_ms += start.elapsed().as_secs_f64() * 1e3;
    }

    /// Wall-clock milliseconds spent serving inside [`Engine::run`].
    pub fn serve_wall_ms(&self) -> f64 {
        self.serve_wall_ms
    }

    /// Per-session and fleet-aggregate views of everything served so far.
    pub fn fleet_summary(&self) -> FleetSummary {
        assert!(self.round > 0, "fleet_summary before any round");
        let per_session: Vec<Summary> = self.sessions.iter().map(|s| s.summary()).collect();
        let merged = Metrics::merged(self.sessions.iter().map(|s| &s.metrics));
        let p_max = self.sessions.iter().map(|s| s.env.num_partitions()).max().unwrap_or(0);
        let queue_waits: Vec<f64> = merged.records.iter().map(|r| r.queue_wait_ms).collect();
        let aggregate = merged.summary(p_max);
        let mean_offloaders =
            self.offload_counts.iter().sum::<usize>() as f64 / self.offload_counts.len() as f64;
        let peak_offloaders = self.offload_counts.iter().copied().max().unwrap_or(0);
        let scheduler = if self.scheduler.is_some() {
            self.cfg.scheduler.policy.name().to_string()
        } else {
            // The PR 1 degenerate case; name it explicitly so JSON
            // consumers can tell it from event-driven FIFO.
            "fifo-lockstep".to_string()
        };
        let serve_ms = self.serve_wall_ms;
        let frames_per_sec = if serve_ms > 0.0 {
            aggregate.frames as f64 / (serve_ms / 1e3)
        } else {
            f64::NAN
        };
        FleetSummary {
            per_session,
            aggregate,
            mean_offloaders,
            peak_offloaders,
            peak_contention_factor: self.cfg.contention.factor(peak_offloaders),
            scheduler,
            select_batch: self.select_batch_effective().to_string(),
            p95_queue_wait_ms: percentile(&queue_waits, 0.95),
            workers: self.cfg.workers.max(1),
            serve_ms,
            frames_per_sec,
            replicas: Vec::new(),
            phases: self.phases.clone(),
        }
    }

    /// Fleet-merged summary over rounds `[from, to)` only — the
    /// `--metrics-every` periodic snapshot stream.  `None` when no
    /// session recorded a frame in the window (e.g. an idle engine).
    pub fn window_summary(&self, from: usize, to: usize) -> Option<Summary> {
        let mut window = Metrics::new();
        let p_max = self.sessions.iter().map(|s| s.env.num_partitions()).max().unwrap_or(0);
        for s in &self.sessions {
            for r in &s.metrics.records {
                if r.t >= from && r.t < to {
                    window.records.push(r.clone());
                }
            }
        }
        if window.records.is_empty() {
            None
        } else {
            Some(window.summary(p_max))
        }
    }
}

/// Per-session video streams draw from a stream-id space disjoint from
/// the environments' (see [`Rng::stream_seed`]).
pub(crate) const VIDEO_STREAM_BASE: u64 = 1 << 32;

/// The per-engine knob set a [`Config`] describes — shared by
/// [`fleet_from_config`] and the cluster builder (every replica's engine
/// is instantiated from this same template).
pub(crate) fn engine_config_from(cfg: &Config) -> EngineConfig {
    EngineConfig {
        frame_interval_ms: 1e3 / cfg.fps,
        contention: Contention::new(cfg.contention_capacity, cfg.contention_slope),
        ingress_mbps: if cfg.ingress_mbps > 0.0 { Some(cfg.ingress_mbps) } else { None },
        scheduler: cfg.scheduler_config(),
        workers: cfg.workers,
        queue_signal: cfg.queue_signal_mode(),
        signal_stagger_ms: cfg.signal_stagger_ms,
        select_batch: SelectBatch::by_name(&cfg.select_batch).expect("validated select-batch"),
        trace_capacity: if cfg.trace.is_empty() { 0 } else { cfg.trace_capacity },
    }
}

/// Assemble the fleet engine a [`Config`] describes: `cfg.sessions`
/// sessions over [`crate::simulator::scenario::fleet_with`] environments
/// (per-session uplinks), each with its own policy instance and video
/// source, coupled by the configured contention/ingress models and the
/// configured edge scheduler.  Every per-session RNG stream is a pure
/// function of `(seed, session index)`, so growing the fleet never
/// perturbs existing sessions' draws.
pub fn fleet_from_config(cfg: &Config) -> Engine {
    let net = crate::models::zoo::by_name(&cfg.model).expect("validated model");
    let device = crate::simulator::profile_by_name(&cfg.device).expect("validated device");
    let edge = crate::simulator::profile_by_name(&cfg.edge).expect("validated edge");
    let envs = crate::simulator::scenario::fleet_with(
        net,
        cfg.sessions,
        cfg.rate_mbps,
        device,
        edge,
        cfg.load,
        cfg.seed,
    );
    let mut engine = Engine::new(engine_config_from(cfg));
    for (i, env) in envs.into_iter().enumerate() {
        let policy = cfg.policy(&env.net, &env.device, &env.edge);
        let source = FrameSource::video(
            Rng::stream_seed(cfg.seed, VIDEO_STREAM_BASE + i as u64),
            cfg.ssim_threshold,
            Weights::new(cfg.l_key, cfg.l_non_key),
        );
        engine.add_session(policy, env, source);
    }
    engine
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::simulator::{Uplink, Workload, DEVICE_MAXN, EDGE_GPU};

    fn policy(net: &crate::models::Network, name: &str, horizon: usize) -> Box<dyn Policy> {
        crate::bandit::by_name(name, net, &DEVICE_MAXN, &EDGE_GPU, horizon, None, None).unwrap()
    }

    fn env(rate: f64, seed: u64) -> Environment {
        Environment::simple(zoo::partnet(), rate, seed)
    }

    #[test]
    fn single_session_round_produces_records() {
        let mut eng = Engine::new(EngineConfig::default());
        let net = zoo::partnet();
        eng.add_session(policy(&net, "mu-linucb", 50), env(10.0, 1), FrameSource::uniform());
        eng.run(50);
        assert_eq!(eng.round(), 50);
        let s = &eng.sessions()[0];
        assert_eq!(s.metrics.records.len(), 50);
        let sum = s.summary();
        assert!(sum.mean_delay_ms.is_finite() && sum.mean_delay_ms > 0.0);
    }

    #[test]
    fn offload_counts_track_policies() {
        // EO sessions offload every round; MO sessions never do.
        let net = zoo::partnet();
        let mut eng = Engine::new(EngineConfig::default());
        eng.add_session(policy(&net, "eo", 20), env(10.0, 1), FrameSource::uniform());
        eng.add_session(policy(&net, "eo", 20), env(10.0, 2), FrameSource::uniform());
        eng.add_session(policy(&net, "mo", 20), env(10.0, 3), FrameSource::uniform());
        eng.run(20);
        assert!(eng.offload_counts().iter().all(|&k| k == 2), "{:?}", eng.offload_counts());
    }

    #[test]
    fn contention_inflates_realized_edge_delays() {
        // Same EO arm, same uplink: an 8-way contended engine must realize
        // strictly larger mean delays than a lone session.
        let run_one = |n: usize| -> f64 {
            let mut eng = Engine::new(EngineConfig {
                contention: Contention::new(1, 0.5),
                ..Default::default()
            });
            let net = zoo::partnet();
            for i in 0..n {
                eng.add_session(policy(&net, "eo", 60), env(10.0, 10 + i as u64), FrameSource::uniform());
            }
            eng.run(60);
            eng.sessions()[0].summary().mean_delay_ms
        };
        let lone = run_one(1);
        let crowded = run_one(8);
        assert!(
            crowded > lone * 1.5,
            "8-way contention should inflate session 0's delay: {lone} -> {crowded}"
        );
    }

    #[test]
    fn shared_ingress_queues_later_sessions() {
        // Both sessions offload the same ψ at the same instant over a slow
        // shared ingress: session 1 must queue behind session 0.
        let net = zoo::partnet();
        let mut eng = Engine::new(EngineConfig {
            ingress_mbps: Some(1.0),
            ..Default::default()
        });
        // Noise-free for a clean ordering comparison.
        let mk = |seed| {
            let mut e = Environment::new(
                net.clone(),
                DEVICE_MAXN,
                EDGE_GPU,
                Workload::constant(1.0),
                Uplink::constant(10.0),
                seed,
            );
            e.noise_std_ms = 0.0;
            e
        };
        eng.add_session(policy(&net, "eo", 4), mk(1), FrameSource::uniform());
        eng.add_session(policy(&net, "eo", 4), mk(1), FrameSource::uniform());
        eng.step();
        let d0 = eng.sessions()[0].metrics.records[0].delay_ms;
        let d1 = eng.sessions()[1].metrics.records[0].delay_ms;
        // ψ_0 of partnet is 12288 bytes = ~98 ms at 1 Mbps: queueing doubles it.
        assert!(d1 > d0 + 50.0, "session 1 should queue behind session 0: {d0} vs {d1}");
    }

    #[test]
    fn event_scheduler_batches_concurrent_offloads() {
        use crate::edge::AdmissionPolicy;
        let net = zoo::partnet();
        let cfg = EngineConfig {
            contention: Contention::new(1, 0.25),
            scheduler: SchedulerConfig::event(AdmissionPolicy::Edf),
            ..Default::default()
        };
        let mut eng = Engine::new(cfg);
        for i in 0..4 {
            eng.add_session(policy(&net, "eo", 30), env(10.0, 1 + i as u64), FrameSource::uniform());
        }
        eng.run(30);
        let stats = eng.scheduler_stats().expect("event mode exposes queue stats");
        assert_eq!(stats.dispatched, 120);
        assert_eq!(stats.rejected, 0);
        assert!(stats.mean_batch_size() > 1.5, "all-EO fleet must batch: {}", stats.mean_batch_size());
        for s in eng.sessions() {
            for r in &s.metrics.records {
                assert!(r.batch_size >= 1, "served frames record their batch");
                assert!(r.queue_wait_ms >= 0.0);
                assert!(!r.rejected);
                assert!(r.delay_ms.is_finite() && r.delay_ms >= 0.0);
            }
        }
        let fs = eng.fleet_summary();
        assert_eq!(fs.scheduler, "edf");
        assert!(fs.aggregate.mean_batch_size > 1.5);
    }

    #[test]
    fn bounded_waiting_room_rejects_and_falls_back_on_device() {
        use crate::edge::AdmissionPolicy;
        let net = zoo::partnet();
        let cfg = EngineConfig {
            contention: Contention::new(1, 0.25),
            scheduler: SchedulerConfig {
                queue_capacity: 2,
                ..SchedulerConfig::event(AdmissionPolicy::Fifo)
            },
            ..Default::default()
        };
        let mut eng = Engine::new(cfg);
        for i in 0..6 {
            eng.add_session(policy(&net, "eo", 10), env(10.0, 1 + i as u64), FrameSource::uniform());
        }
        eng.step();
        // Six EO offloads into a 2-slot waiting room: 2 served, 4 bounced.
        let stats = eng.scheduler_stats().unwrap();
        assert_eq!(stats.dispatched, 2);
        assert_eq!(stats.rejected, 4);
        let rejected = eng
            .sessions()
            .iter()
            .filter(|s| s.metrics.records[0].rejected)
            .count();
        assert_eq!(rejected, 4);
        for s in eng.sessions() {
            let r = &s.metrics.records[0];
            if r.rejected {
                assert_eq!(r.batch_size, 0);
                assert!(r.delay_ms > 0.0, "fallback still costs device time");
            }
        }
        assert_eq!(eng.fleet_summary().aggregate.rejected_offloads, 4);
    }

    #[test]
    fn sharded_step_matches_single_threaded_step() {
        // The in-module smoke version of the tests/fleet.rs pin: a
        // 6-session contended engine produces byte-identical records at
        // workers = 1 and workers = 3.
        let build = |workers: usize| {
            let net = zoo::partnet();
            let mut eng = Engine::new(EngineConfig {
                contention: Contention::new(1, 0.5),
                ingress_mbps: Some(150.0),
                workers,
                ..Default::default()
            });
            for i in 0..6 {
                eng.add_session(
                    policy(&net, "mu-linucb", 40),
                    env(8.0 + i as f64, 30 + i as u64),
                    FrameSource::uniform(),
                );
            }
            eng.run(40);
            eng
        };
        let solo = build(1);
        let sharded = build(3);
        assert_eq!(solo.offload_counts(), sharded.offload_counts());
        for (a, b) in solo.sessions().iter().zip(sharded.sessions()) {
            assert_eq!(a.metrics.records.len(), b.metrics.records.len());
            for (ra, rb) in a.metrics.records.iter().zip(&b.metrics.records) {
                assert_eq!(ra.p, rb.p, "s{} t={}", a.id, ra.t);
                assert_eq!(ra.delay_ms, rb.delay_ms, "s{} t={}", a.id, ra.t);
                assert_eq!(ra.expected_ms, rb.expected_ms, "s{} t={}", a.id, ra.t);
                assert_eq!(ra.queue_wait_ms, rb.queue_wait_ms, "s{} t={}", a.id, ra.t);
            }
        }
    }

    #[test]
    fn more_workers_than_sessions_is_fine() {
        let net = zoo::partnet();
        let mut eng = Engine::new(EngineConfig { workers: 8, ..Default::default() });
        eng.add_session(policy(&net, "mu-linucb", 20), env(10.0, 1), FrameSource::uniform());
        eng.add_session(policy(&net, "eo", 20), env(10.0, 2), FrameSource::uniform());
        eng.run(20);
        assert_eq!(eng.round(), 20);
        for s in eng.sessions() {
            assert_eq!(s.metrics.records.len(), 20);
        }
    }

    #[test]
    fn run_accumulates_wall_time_for_throughput() {
        let net = zoo::partnet();
        let mut eng = Engine::new(EngineConfig::default());
        eng.add_session(policy(&net, "eo", 30), env(10.0, 1), FrameSource::uniform());
        eng.run(30);
        assert!(eng.serve_wall_ms() > 0.0);
        let fs = eng.fleet_summary();
        assert_eq!(fs.workers, 1);
        assert!(fs.serve_ms > 0.0);
        assert!(fs.frames_per_sec.is_finite() && fs.frames_per_sec > 0.0);
    }

    #[test]
    #[should_panic(expected = "queue-signal")]
    fn queue_signal_requires_the_event_scheduler() {
        Engine::new(EngineConfig {
            queue_signal: QueueSignal::Full,
            ..Default::default()
        });
    }

    #[test]
    fn queue_aware_round_populates_event_accounting() {
        use crate::edge::AdmissionPolicy;
        let net = zoo::partnet();
        let mut eng = Engine::new(EngineConfig {
            contention: Contention::new(1, 0.25),
            scheduler: SchedulerConfig::event(AdmissionPolicy::Fifo),
            queue_signal: QueueSignal::Full,
            ..Default::default()
        });
        for i in 0..4 {
            eng.add_session(
                policy(&net, "mu-linucb", 40),
                env(10.0, 1 + i as u64),
                FrameSource::uniform(),
            );
        }
        eng.run(40);
        for s in eng.sessions() {
            assert_eq!(s.metrics.records.len(), 40);
            for r in &s.metrics.records {
                assert!(r.event_expected_ms.is_finite() && r.event_expected_ms >= 0.0);
                assert!(
                    r.event_oracle_ms <= r.event_expected_ms + 1e-9,
                    "event oracle must not exceed the chosen arm: {} vs {}",
                    r.event_oracle_ms,
                    r.event_expected_ms
                );
                assert!(r.event_oracle_p <= s.env.num_partitions());
            }
            let sum = s.summary();
            assert!(sum.event_regret_ms >= -1e-9, "event regret is non-negative per frame");
        }
    }

    #[test]
    fn lockstep_rounds_mirror_legacy_oracle_into_event_fields() {
        let net = zoo::partnet();
        let mut eng = Engine::new(EngineConfig::default());
        eng.add_session(policy(&net, "mu-linucb", 30), env(10.0, 5), FrameSource::uniform());
        eng.run(30);
        for r in &eng.sessions()[0].metrics.records {
            assert_eq!(r.event_expected_ms, r.expected_ms);
            assert_eq!(r.event_oracle_p, r.oracle_p);
            assert_eq!(r.event_oracle_ms, r.oracle_ms);
            assert!(!r.deadline_miss, "no deadline configured");
        }
    }

    #[test]
    fn deadline_misses_count_in_lockstep_mode_too() {
        // A 1 ms budget on the lockstep path: every frame misses —
        // deadline accounting is independent of EDF admission.
        let net = zoo::partnet();
        let mut eng = Engine::new(EngineConfig {
            scheduler: SchedulerConfig { deadline_ms: 1.0, ..SchedulerConfig::lockstep_fifo() },
            ..Default::default()
        });
        assert!(eng.cfg.scheduler.is_lockstep(), "deadline alone must not leave lockstep");
        eng.add_session(policy(&net, "eo", 20), env(10.0, 2), FrameSource::uniform());
        eng.run(20);
        let sum = eng.sessions()[0].summary();
        assert_eq!(sum.deadline_misses, 20);
        assert_eq!(eng.fleet_summary().aggregate.deadline_misses, 20);
    }

    #[test]
    fn empty_engine_step_is_a_noop() {
        // A cluster replica can hold zero sessions between migrations:
        // its rounds must be explicit no-ops that still advance the
        // round counter and log k_t = 0 so replicas stay aligned.
        let mut eng = Engine::new(EngineConfig::default());
        eng.step();
        eng.step();
        assert_eq!(eng.round(), 2);
        assert_eq!(eng.offload_counts(), &[0, 0]);
        assert_eq!(eng.num_sessions(), 0);
        // The sharded path is a no-op too (no shard arithmetic on 0).
        let mut sharded = Engine::new(EngineConfig { workers: 4, ..Default::default() });
        sharded.run(3);
        assert_eq!(sharded.round(), 3);
        assert_eq!(sharded.offload_counts(), &[0, 0, 0]);
    }

    #[test]
    fn sessions_detach_and_reattach_in_id_order() {
        let net = zoo::partnet();
        let mut eng = Engine::new(EngineConfig::default());
        for i in 0..4 {
            eng.add_session(
                policy(&net, "eo", 10),
                env(10.0, 1 + i as u64),
                FrameSource::uniform(),
            );
        }
        let s2 = eng.remove_session(2);
        assert_eq!(s2.id, 2);
        assert_eq!(
            eng.sessions().iter().map(|s| s.id).collect::<Vec<_>>(),
            vec![0, 1, 3]
        );
        eng.push_session(s2);
        assert_eq!(
            eng.sessions().iter().map(|s| s.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3],
            "push_session restores canonical id order"
        );
        eng.run(5);
        for s in eng.sessions() {
            assert_eq!(s.metrics.records.len(), 5);
        }
    }

    #[test]
    fn signal_stagger_shifts_published_waits_per_session() {
        use crate::edge::{signal_phase, AdmissionPolicy};
        // Idle queue + queue-signal wait: on the warm-up frame every
        // session picks arm 0 and the recorded prediction is exactly the
        // published wait (the fresh ridge predicts 0), so the stagger
        // offset is directly visible: session 0 stays unshifted (phase
        // 0), session 1 gains stagger·phase(1).
        let build = |stagger: f64| {
            let net = zoo::partnet();
            let mut sc = SchedulerConfig::event(AdmissionPolicy::Fifo);
            sc.max_batch = 1;
            sc.batch_window_ms = 0.0;
            let mut eng = Engine::new(EngineConfig {
                scheduler: sc,
                queue_signal: QueueSignal::Wait,
                signal_stagger_ms: stagger,
                ..Default::default()
            });
            for i in 0..2 {
                eng.add_session(
                    policy(&net, "mu-linucb", 4),
                    env(10.0, 1 + i as u64),
                    FrameSource::uniform(),
                );
            }
            eng.step();
            eng
        };
        let base = build(0.0);
        let shifted = build(40.0);
        let pred = |e: &Engine, i: usize| {
            e.sessions()[i].metrics.records[0].predicted_edge_ms.expect("warm-up offloads")
        };
        assert_eq!(pred(&base, 0), pred(&shifted, 0), "session 0 is never shifted");
        let delta = pred(&shifted, 1) - pred(&base, 1);
        let want = 40.0 * signal_phase(1);
        assert!(
            (delta - want).abs() < 1e-9,
            "session 1's published wait should shift by {want}, got {delta}"
        );
    }

    #[test]
    #[should_panic(expected = "signal-stagger")]
    fn signal_stagger_requires_an_active_queue_signal() {
        Engine::new(EngineConfig { signal_stagger_ms: 5.0, ..Default::default() });
    }

    #[test]
    fn fleet_from_config_builds_n_sessions() {
        let args = crate::util::cli::Args::parse(
            "fleet --sessions 3 --model partnet --frames 30 --rate 10"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let cfg = Config::from_args(&args).unwrap();
        let mut eng = fleet_from_config(&cfg);
        assert_eq!(eng.num_sessions(), 3);
        eng.run(cfg.frames);
        let fs = eng.fleet_summary();
        assert_eq!(fs.per_session.len(), 3);
        assert_eq!(fs.aggregate.frames, 90);
        assert!(fs.peak_contention_factor >= 1.0);
    }
}
