//! Typed snapshot schema: the on-disk (and on-wire) form of a complete
//! serving fleet (DESIGN.md §15).
//!
//! The schema is a small tower of plain structs mirroring the runtime
//! tiers — [`SessionState`] → [`EngineState`] → [`ReplicaState`] →
//! [`ClusterState`] → [`FleetSnapshot`] — each with a `to_json` /
//! `from_json` pair built on the typed decode layer in [`crate::util::json`].
//! Two representation rules make the round-trip *bit*-exact:
//!
//! * every f64 travels as its 16-hex-digit IEEE-754 bit pattern
//!   ([`crate::util::json::f64_bits`]), so NaN sentinels, ±∞ deadlines
//!   and −0.0 all survive;
//! * the dense per-session state (policy cold arena + env/source
//!   cursors, packed frame records, packed trace backlog, ingress and
//!   scheduler legs) travels as hex-encoded byte strings of the same
//!   little-endian arenas the hibernation subsystem uses (DESIGN.md
//!   §14) — the snapshot *is* the hibernation format, lifted to disk.
//!
//! Decode failures name the exact field with a dotted path
//! (```snapshot.cluster.replicas[2].engine.round`: expected integer``)
//! and JSON syntax errors carry a byte offset, so a truncated or
//! hand-mangled `--resume` file dies with a friendly CLI error, never a
//! panic (exercised in `rust/tests/snapshot.rs`).
//!
//! The same [`EngineState`] value is the bootstrap/finish payload of the
//! process-per-replica protocol ([`super::protocol`]): a child process
//! is "resumed" from its replica's slice of the snapshot, which is what
//! makes distributed runs bit-identical to in-process runs.

use crate::config::Config;
use crate::simulator::{compute, Workload};
use crate::util::json::{
    self, bytes_hex, f64_bits, f64s_bits, field, field_arr, field_bool, field_bytes_hex,
    field_f64s_bits, field_str, field_u64, field_usize, field_usizes, obj, Json, JsonError,
};
use anyhow::Context;

/// Schema version stamped into every snapshot; bump on any wire change.
pub const SNAPSHOT_VERSION: usize = 1;

/// The `kind` tag distinguishing fleet snapshots from the repo's other
/// JSON artifacts.
pub const SNAPSHOT_KIND: &str = "ans-fleet-snapshot";

type Result<T> = std::result::Result<T, JsonError>;

// ---------------------------------------------------------------------------
// Session tier.
// ---------------------------------------------------------------------------

/// One session's complete mutable state: identity, residency, and the
/// packed arenas.  `arena` is the hibernation cold image (policy state
/// via `Policy::pack_cold`, then env cursor, then source cursor — the
/// exact `Engine::hibernate_session` order); `records` is the packed
/// per-frame metrics history.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionState {
    pub id: usize,
    pub active: bool,
    /// Ridge-store slot index the session's policy occupied.
    pub slot: usize,
    pub arena: Vec<u8>,
    pub records: Vec<u8>,
}

impl SessionState {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("id", Json::from(self.id)),
            ("active", Json::from(self.active)),
            ("slot", Json::from(self.slot)),
            ("arena", bytes_hex(&self.arena)),
            ("records", bytes_hex(&self.records)),
        ])
    }

    pub fn from_json(v: &Json, path: &str) -> Result<SessionState> {
        Ok(SessionState {
            id: field_usize(v, path, "id")?,
            active: field_bool(v, path, "active")?,
            slot: field_usize(v, path, "slot")?,
            arena: field_bytes_hex(v, path, "arena")?,
            records: field_bytes_hex(v, path, "records")?,
        })
    }
}

// ---------------------------------------------------------------------------
// Engine tier.
// ---------------------------------------------------------------------------

/// One engine core's complete mutable state, captured at a round
/// boundary by [`super::engine::Engine::snapshot_state`] and replayed by
/// [`super::engine::Engine::restore_state`].  Structure (worker pool,
/// contention model, scheduler configuration) is *not* here — it is
/// rebuilt from the embedded [`Config`]; this is only what a run
/// mutates.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineState {
    pub round: usize,
    pub next_id: usize,
    /// Concurrent offloaders of the previous round (the contention
    /// coupling input of the next one).
    pub offloaders_last: usize,
    pub offload_counts: Vec<usize>,
    /// Ridge-store slot-window size; sessions reference slots below it.
    pub store_slots: usize,
    /// Free slots, sorted descending (the allocator's own order).
    pub free_slots: Vec<usize>,
    /// Packed shared-ingress queue state (empty when ingress is off).
    pub ingress: Vec<u8>,
    /// Packed edge-scheduler state: waiting room, virtual clocks, event
    /// queue (empty in lockstep mode).
    pub scheduler: Vec<u8>,
    pub sessions: Vec<SessionState>,
    /// Packed trace backlog (count-prefixed `TraceEvent`s): the full
    /// event history up to the snapshot, so a resumed run drains the
    /// same trace an unbroken run would.
    pub trace: Vec<u8>,
    pub trace_dropped: u64,
}

impl EngineState {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("round", Json::from(self.round)),
            ("next_id", Json::from(self.next_id)),
            ("offloaders_last", Json::from(self.offloaders_last)),
            ("offload_counts", Json::from(self.offload_counts.clone())),
            ("store_slots", Json::from(self.store_slots)),
            ("free_slots", Json::from(self.free_slots.clone())),
            ("ingress", bytes_hex(&self.ingress)),
            ("scheduler", bytes_hex(&self.scheduler)),
            (
                "sessions",
                Json::Arr(self.sessions.iter().map(SessionState::to_json).collect()),
            ),
            ("trace", bytes_hex(&self.trace)),
            ("trace_dropped", Json::from(self.trace_dropped as usize)),
        ])
    }

    pub fn from_json(v: &Json, path: &str) -> Result<EngineState> {
        let sessions = field_arr(v, path, "sessions")?
            .iter()
            .enumerate()
            .map(|(i, s)| SessionState::from_json(s, &format!("{path}.sessions[{i}]")))
            .collect::<Result<Vec<_>>>()?;
        let state = EngineState {
            round: field_usize(v, path, "round")?,
            next_id: field_usize(v, path, "next_id")?,
            offloaders_last: field_usize(v, path, "offloaders_last")?,
            offload_counts: field_usizes(v, path, "offload_counts")?,
            store_slots: field_usize(v, path, "store_slots")?,
            free_slots: field_usizes(v, path, "free_slots")?,
            ingress: field_bytes_hex(v, path, "ingress")?,
            scheduler: field_bytes_hex(v, path, "scheduler")?,
            sessions,
            trace: field_bytes_hex(v, path, "trace")?,
            trace_dropped: field_u64(v, path, "trace_dropped")?,
        };
        for (i, s) in state.sessions.iter().enumerate() {
            if s.slot >= state.store_slots {
                return Err(JsonError(format!(
                    "`{path}.sessions[{i}].slot`: slot {} outside the {}-slot store window",
                    s.slot, state.store_slots
                )));
            }
        }
        Ok(state)
    }
}

// ---------------------------------------------------------------------------
// Cluster tier.
// ---------------------------------------------------------------------------

/// One replica: its spec (edge profile by zoo name + exogenous workload
/// schedule), migration counters, and its engine core's state.
#[derive(Debug, Clone)]
pub struct ReplicaState {
    pub id: usize,
    pub label: String,
    /// Edge compute profile, by `compute::profile_by_name` name.
    pub edge: String,
    pub load: Workload,
    pub migrations_in: usize,
    pub migrations_out: usize,
    pub engine: EngineState,
}

impl ReplicaState {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("id", Json::from(self.id)),
            ("label", Json::from(self.label.clone())),
            ("edge", Json::from(self.edge.clone())),
            ("load", workload_to_json(&self.load)),
            ("migrations_in", Json::from(self.migrations_in)),
            ("migrations_out", Json::from(self.migrations_out)),
            ("engine", self.engine.to_json()),
        ])
    }

    pub fn from_json(v: &Json, path: &str) -> Result<ReplicaState> {
        let edge = field_str(v, path, "edge")?.to_string();
        if compute::profile_by_name(&edge).is_none() {
            return Err(JsonError(format!(
                "`{path}.edge`: unknown compute profile `{edge}`"
            )));
        }
        Ok(ReplicaState {
            id: field_usize(v, path, "id")?,
            label: field_str(v, path, "label")?.to_string(),
            edge,
            load: workload_from_json(field(v, path, "load")?, &format!("{path}.load"))?,
            migrations_in: field_usize(v, path, "migrations_in")?,
            migrations_out: field_usize(v, path, "migrations_out")?,
            engine: EngineState::from_json(field(v, path, "engine")?, &format!("{path}.engine"))?,
        })
    }
}

/// The routed replica tier's state: router bookkeeping plus one
/// [`ReplicaState`] per replica.  A single-engine fleet is the 1-replica
/// special case — there is one snapshot schema, not two.
#[derive(Debug, Clone)]
pub struct ClusterState {
    pub round: usize,
    pub migrations: usize,
    /// Session id → owning replica index.
    pub assignment: Vec<usize>,
    /// The placement router's per-replica committed-load estimates.
    pub base_load: Vec<f64>,
    pub replicas: Vec<ReplicaState>,
}

impl ClusterState {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("round", Json::from(self.round)),
            ("migrations", Json::from(self.migrations)),
            ("assignment", Json::from(self.assignment.clone())),
            ("base_load", f64s_bits(&self.base_load)),
            (
                "replicas",
                Json::Arr(self.replicas.iter().map(ReplicaState::to_json).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json, path: &str) -> Result<ClusterState> {
        let replicas = field_arr(v, path, "replicas")?
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaState::from_json(r, &format!("{path}.replicas[{i}]")))
            .collect::<Result<Vec<_>>>()?;
        let state = ClusterState {
            round: field_usize(v, path, "round")?,
            migrations: field_usize(v, path, "migrations")?,
            assignment: field_usizes(v, path, "assignment")?,
            base_load: field_f64s_bits(v, path, "base_load")?,
            replicas,
        };
        if state.replicas.is_empty() {
            return Err(JsonError(format!("`{path}.replicas`: snapshot has no replicas")));
        }
        if state.base_load.len() != state.replicas.len() {
            return Err(JsonError(format!(
                "`{path}.base_load`: {} entries for {} replicas",
                state.base_load.len(),
                state.replicas.len()
            )));
        }
        for (i, &r) in state.assignment.iter().enumerate() {
            if r >= state.replicas.len() {
                return Err(JsonError(format!(
                    "`{path}.assignment[{i}]`: replica {r} out of range (cluster has {})",
                    state.replicas.len()
                )));
            }
        }
        for (i, r) in state.replicas.iter().enumerate() {
            if r.id != i {
                return Err(JsonError(format!(
                    "`{path}.replicas[{i}].id`: expected {i}, got {} (replicas must be in \
                     canonical order)",
                    r.id
                )));
            }
        }
        Ok(state)
    }
}

// ---------------------------------------------------------------------------
// Fleet tier: the on-disk document.
// ---------------------------------------------------------------------------

/// The complete on-disk snapshot: the run's [`Config`] (so `--resume`
/// rebuilds identical structure — policies, schedulers, worker pools,
/// and crucially the original `frames` horizon the learners' forced
/// schedules were sized against) plus the [`ClusterState`].
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    pub config: Config,
    pub cluster: ClusterState,
}

impl FleetSnapshot {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("kind", Json::from(SNAPSHOT_KIND)),
            ("version", Json::from(SNAPSHOT_VERSION)),
            ("config", self.config.to_json()),
            ("cluster", self.cluster.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<FleetSnapshot> {
        let kind = field_str(v, "snapshot", "kind")?;
        anyhow::ensure!(
            kind == SNAPSHOT_KIND,
            "not a fleet snapshot: kind is `{kind}`, expected `{SNAPSHOT_KIND}`"
        );
        let version = field_usize(v, "snapshot", "version")?;
        anyhow::ensure!(
            version == SNAPSHOT_VERSION,
            "snapshot schema version {version} is not supported (this build reads \
             version {SNAPSHOT_VERSION})"
        );
        let config = Config::from_json_value(field(v, "snapshot", "config")?)
            .context("decoding `snapshot.config`")?;
        let cluster = ClusterState::from_json(field(v, "snapshot", "cluster")?, "snapshot.cluster")?;
        anyhow::ensure!(
            cluster.replicas.len() == config.replicas,
            "snapshot has {} replicas but its embedded config says {}",
            cluster.replicas.len(),
            config.replicas
        );
        Ok(FleetSnapshot { config, cluster })
    }

    /// Serialize and write to `path` (parent directories created).
    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating snapshot directory for {path}"))?;
            }
        }
        let mut out = self.to_json().to_string();
        out.push('\n');
        std::fs::write(path, out).with_context(|| format!("writing snapshot {path}"))?;
        Ok(())
    }

    /// Read and decode `path`.  Every failure mode is a named error: a
    /// missing file says so, truncated/invalid JSON names the byte
    /// offset, and a schema mismatch names the exact dotted field.
    pub fn load(path: &str) -> anyhow::Result<FleetSnapshot> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading snapshot {path}"))?;
        let v = Json::parse(&text)
            .map_err(anyhow::Error::from)
            .with_context(|| format!("snapshot {path} is not valid JSON"))?;
        FleetSnapshot::from_json(&v).with_context(|| format!("decoding snapshot {path}"))
    }
}

// ---------------------------------------------------------------------------
// Workload wire form.
// ---------------------------------------------------------------------------

/// Encode a [`Workload`] schedule: `{"constant": bits}` or
/// `{"steps": [[frame, bits], ...]}` (loads as f64 bit patterns).
pub fn workload_to_json(w: &Workload) -> Json {
    match w {
        Workload::Constant(l) => obj(vec![("constant", f64_bits(*l))]),
        Workload::Steps(steps) => obj(vec![(
            "steps",
            Json::Arr(
                steps
                    .iter()
                    .map(|&(t, l)| Json::Arr(vec![Json::from(t), f64_bits(l)]))
                    .collect(),
            ),
        )]),
    }
}

/// Decode a value written by [`workload_to_json`].
pub fn workload_from_json(v: &Json, path: &str) -> Result<Workload> {
    if let Some(l) = v.opt("constant") {
        return Ok(Workload::Constant(json::parse_f64_bits(
            l,
            &format!("{path}.constant"),
        )?));
    }
    if let Some(arr) = v.opt("steps") {
        let arr = arr
            .as_arr()
            .map_err(|e| JsonError(format!("`{path}.steps`: {}", e.0)))?;
        let mut steps = Vec::with_capacity(arr.len());
        for (i, entry) in arr.iter().enumerate() {
            let p = format!("{path}.steps[{i}]");
            let pair = entry.as_arr().map_err(|e| JsonError(format!("`{p}`: {}", e.0)))?;
            if pair.len() != 2 {
                return Err(JsonError(format!(
                    "`{p}`: expected [frame, load] pair, got {} elements",
                    pair.len()
                )));
            }
            let t = pair[0]
                .as_usize()
                .map_err(|e| JsonError(format!("`{p}[0]`: {}", e.0)))?;
            let l = json::parse_f64_bits(&pair[1], &format!("{p}[1]"))?;
            steps.push((t, l));
        }
        if steps.is_empty() || steps[0].0 != 0 {
            return Err(JsonError(format!(
                "`{path}.steps`: schedule must start at frame 0"
            )));
        }
        if !steps.windows(2).all(|w| w[0].0 < w[1].0) {
            return Err(JsonError(format!(
                "`{path}.steps`: frames must be strictly increasing"
            )));
        }
        return Ok(Workload::Steps(steps));
    }
    Err(JsonError(format!(
        "`{path}`: workload needs a `constant` or `steps` field"
    )))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_engine() -> EngineState {
        EngineState {
            round: 57,
            next_id: 3,
            offloaders_last: 2,
            offload_counts: vec![1, 0, 4],
            store_slots: 4,
            free_slots: vec![3],
            ingress: vec![1, 2, 3, 0xff],
            scheduler: vec![],
            sessions: vec![
                SessionState {
                    id: 0,
                    active: true,
                    slot: 0,
                    arena: (0..=255).collect(),
                    records: vec![9, 8, 7],
                },
                SessionState { id: 2, active: false, slot: 2, arena: vec![], records: vec![] },
            ],
            trace: vec![0; 9],
            trace_dropped: 12,
        }
    }

    #[test]
    fn engine_state_round_trips_through_text() {
        let state = sample_engine();
        let text = state.to_json().to_string();
        let back = EngineState::from_json(&Json::parse(&text).unwrap(), "e").unwrap();
        assert_eq!(back, state);
    }

    #[test]
    fn cluster_state_round_trips_with_odd_floats() {
        let state = ClusterState {
            round: 100,
            migrations: 7,
            assignment: vec![0, 1, 0],
            base_load: vec![0.0, f64::NAN],
            replicas: vec![
                ReplicaState {
                    id: 0,
                    label: "r0".into(),
                    edge: "edge_gpu_1080ti".into(),
                    load: Workload::Constant(1.0),
                    migrations_in: 1,
                    migrations_out: 0,
                    engine: sample_engine(),
                },
                ReplicaState {
                    id: 1,
                    label: "r1".into(),
                    edge: "gpu".into(),
                    load: Workload::Steps(vec![(0, 6.0), (50, 1.0)]),
                    migrations_in: 0,
                    migrations_out: 1,
                    engine: sample_engine(),
                },
            ],
        };
        let text = state.to_json().to_string();
        let back = ClusterState::from_json(&Json::parse(&text).unwrap(), "c").unwrap();
        assert_eq!(back.round, state.round);
        assert_eq!(back.assignment, state.assignment);
        assert_eq!(back.base_load[0].to_bits(), state.base_load[0].to_bits());
        assert!(back.base_load[1].is_nan());
        assert_eq!(back.replicas.len(), 2);
        assert_eq!(back.replicas[1].engine, state.replicas[1].engine);
        match &back.replicas[1].load {
            Workload::Steps(s) => assert_eq!(s, &vec![(0, 6.0), (50, 1.0)]),
            other => panic!("expected steps workload, got {other:?}"),
        }
    }

    #[test]
    fn decode_errors_name_the_field() {
        let mut state = sample_engine();
        state.sessions[1].slot = 9; // outside the 4-slot window
        let err =
            EngineState::from_json(&Json::parse(&state.to_json().to_string()).unwrap(), "e")
                .unwrap_err();
        assert!(err.0.contains("e.sessions[1].slot"), "{err}");

        let v = Json::parse(r#"{"round": 1}"#).unwrap();
        let err = EngineState::from_json(&v, "snapshot.engine").unwrap_err();
        assert!(err.0.contains("snapshot.engine"), "{err}");

        let bad_edge = Json::parse(
            r#"{"id":0,"label":"r0","edge":"tpu","load":{"constant":"3ff0000000000000"},
                "migrations_in":0,"migrations_out":0,"engine":{}}"#,
        )
        .unwrap();
        let err = ReplicaState::from_json(&bad_edge, "r").unwrap_err();
        assert!(err.0.contains("r.edge") && err.0.contains("tpu"), "{err}");
    }

    #[test]
    fn workload_wire_rejects_malformed_schedules() {
        let ok = workload_to_json(&Workload::Steps(vec![(0, 1.0), (10, 2.0)]));
        match workload_from_json(&ok, "w").unwrap() {
            Workload::Steps(s) => assert_eq!(s.len(), 2),
            other => panic!("{other:?}"),
        }
        let bad = Json::parse(r#"{"steps": [[5, "3ff0000000000000"]]}"#).unwrap();
        assert!(workload_from_json(&bad, "w").unwrap_err().0.contains("frame 0"));
        let empty = Json::parse(r#"{}"#).unwrap();
        assert!(workload_from_json(&empty, "w").unwrap_err().0.contains("`w`"));
    }
}
