//! Open-world fleet driver: deterministic session churn over one engine.
//!
//! Closed-world benchmarks hold the population fixed; a real edge fleet
//! does not.  This driver runs a [`ChurnSchedule`] — a deterministic
//! open-loop arrival/departure process with per-session duty cycles —
//! against one [`Engine`], applying every membership change at round
//! boundaries only (the engine's contract):
//!
//! 1. **Departures** — sessions whose lifespan expires this round are
//!    evicted (resident) or dropped from cold storage (hibernated); their
//!    metrics survive for end-of-run reporting.
//! 2. **Sleeps** — sessions whose duty burst ends are hibernated into a
//!    byte arena ([`super::ColdSession`]) when the policy supports it, or
//!    parked resident-idle otherwise.
//! 3. **Wakes** — sessions whose next burst starts are woken from cold
//!    (slot rebind + arena unpack) or flipped back to active.
//! 4. **Arrivals** — new global ids are admitted with freshly built
//!    sessions; each session's whole life is a pure function of
//!    `(seed, id)`, so lazily materializing session 50 000 cannot perturb
//!    anyone else.  Admits that arrive off-duty hibernate immediately,
//!    so residency tracks the active set from round 0.
//!
//! Every phase transition is found in O(transitions) via cycle-offset
//! buckets (`(arrival + phase) mod period` congruence classes) and a
//! departure ring — the driver never scans the live population, and the
//! engine's active-set index keeps the round itself O(active).  With
//! [`OpenWorld::prepare`] pre-sizing shells, arenas, and buckets, a
//! steady-state churn round (admission + hibernation included) performs
//! zero heap allocations — audited in `rust/benches/hotpath.rs`.

use std::collections::HashMap;
use std::mem::take;

use crate::bandit::policy::Policy;
use crate::simulator::scenario::ChurnSchedule;
use crate::simulator::Environment;

use super::metrics::Metrics;
use super::{ColdSession, Engine, EngineConfig, FrameSource, Session};

/// Builds the structural parts of global session `g` — policy,
/// environment, frame source.  Must be deterministic in `g`: a wake
/// shell built by the same closure must match the original session's
/// construction parameters bit-for-bit (the arena restores state; the
/// builder restores structure).
pub type SessionBuilder = Box<dyn FnMut(u64) -> (Box<dyn Policy>, Environment, FrameSource)>;

/// Aggregate fleet state at a round boundary (see [`OpenWorld::stats`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OpenWorldStats {
    /// Rounds completed so far.
    pub rounds: usize,
    /// Live sessions: resident (active + idle) plus hibernated.
    pub live: usize,
    /// Sessions resident in the engine (holding a store slot).
    pub resident: usize,
    /// Resident sessions participating in rounds.
    pub active: usize,
    /// Hibernated sessions (byte-cost only).
    pub cold: usize,
    /// Total bytes across all cold arenas.
    pub cold_bytes: usize,
    /// Lifetime admission / eviction / hibernate / wake counts.
    pub admissions: u64,
    pub evictions: u64,
    pub hibernates: u64,
    pub wakes: u64,
    /// Frames offered to the engine (one per active session per round).
    pub frames: u64,
}

/// The open-world fleet: one [`Engine`] plus the churn machinery that
/// admits, parks, wakes, and evicts sessions per a [`ChurnSchedule`].
pub struct OpenWorld {
    engine: Engine,
    schedule: ChurnSchedule,
    builder: SessionBuilder,
    /// Rounds completed (== the engine's round counter).
    t: usize,
    /// Wake transitions bucketed by `t mod period`: id `g` appears in
    /// bucket `(arrival − phase) mod period`, the congruence class of
    /// every round where its cycle offset is 0.  Dead ids are purged
    /// lazily (each costs at most one extra visit).
    wake_bucket: Vec<Vec<u64>>,
    /// Sleep transitions: bucket `(arrival − phase + on) mod period`,
    /// offset `on` — the round a burst ends.
    sleep_bucket: Vec<Vec<u64>>,
    /// Departure ring: slot `departs_at mod ring_len`; the ring is longer
    /// than any possible lifespan, so a slot never holds two horizons.
    departs: Vec<Vec<u64>>,
    /// Cold storage: hibernated sessions by global id.  Never iterated
    /// for behavior (only keyed access), so map order cannot leak into
    /// results.
    cold: HashMap<usize, ColdSession>,
    /// Pre-built session shells (admissions and wakes) keyed by global
    /// id — filled by [`OpenWorld::prepare`] so churn rounds inside the
    /// prepared horizon never construct sessions.
    shells: HashMap<usize, Session>,
    /// Recycled cold arenas: hibernation pops one, wake pushes it back.
    arena_pool: Vec<Vec<u8>>,
    /// Metrics of departed sessions, in departure order.
    departed: Vec<(usize, Metrics)>,
    /// Incrementally maintained active count (avoids an O(resident) scan
    /// per round for throughput accounting).
    active_now: usize,
    admissions: u64,
    evictions: u64,
    hibernates: u64,
    wakes: u64,
    frames: u64,
}

impl OpenWorld {
    /// Build the fleet and admit the construction-time cohort (global
    /// ids `0..schedule.initial`, arrival round 0).
    pub fn new(cfg: EngineConfig, schedule: ChurnSchedule, builder: SessionBuilder) -> OpenWorld {
        let period = schedule.period;
        // Longer than any drawn lifespan (`< ⌈3·mean/2⌉`), so each ring
        // slot holds exactly one departure horizon.
        let ring_len = (3 * schedule.mean_lifespan).div_ceil(2) + 1;
        let mut world = OpenWorld {
            engine: Engine::new(cfg),
            schedule,
            builder,
            t: 0,
            wake_bucket: (0..period).map(|_| Vec::new()).collect(),
            sleep_bucket: (0..period).map(|_| Vec::new()).collect(),
            departs: (0..ring_len).map(|_| Vec::new()).collect(),
            cold: HashMap::new(),
            shells: HashMap::new(),
            arena_pool: Vec::new(),
            departed: Vec::new(),
            active_now: 0,
            admissions: 0,
            evictions: 0,
            hibernates: 0,
            wakes: 0,
            frames: 0,
        };
        for g in 0..world.schedule.initial as u64 {
            world.admit(g, 0);
        }
        world
    }

    fn build_session(&mut self, g: u64) -> Session {
        let (policy, env, source) = (self.builder)(g);
        Session::new(g as usize, policy, env, source)
    }

    /// Admit global id `g` at round boundary `t`: attach a session
    /// (pre-built shell if available), register its departure and duty
    /// transitions, and park it idle if it arrives mid-cycle outside its
    /// burst.
    fn admit(&mut self, g: u64, t: usize) {
        let plan = self.schedule.plan(g);
        let shell = match self.shells.remove(&(g as usize)) {
            Some(shell) => shell,
            None => self.build_session(g),
        };
        self.engine.attach_session(shell);
        let ring = plan.departs_at() % self.departs.len();
        self.departs[ring].push(g);
        if plan.on < plan.period {
            let w = (plan.arrival + plan.period - plan.phase) % plan.period;
            self.wake_bucket[w].push(g);
            self.sleep_bucket[(w + plan.on) % plan.period].push(g);
        }
        if plan.active_at(t) {
            self.active_now += 1;
        } else if self.engine.can_hibernate(g as usize) {
            // Off-duty at admission: go straight to cold so residency
            // tracks the active set from round 0 — a 100k-live fleet at
            // 1% duty never holds 100k resident sessions, even
            // transiently (its wake bucket revives it on-burst).
            let arena = self.arena_pool.pop().unwrap_or_default();
            let cold = self.engine.hibernate_session(g as usize, arena);
            self.cold.insert(g as usize, cold);
            self.hibernates += 1;
        } else {
            self.engine.set_active(g as usize, false);
        }
        self.admissions += 1;
    }

    /// Apply every membership change due at the boundary of round `t`,
    /// in the canonical order: departures, sleeps, wakes, arrivals.
    fn boundary(&mut self, t: usize) {
        // 1. Departures: evict residents, drop cold sessions; keep metrics.
        let idx = t % self.departs.len();
        let mut leaving = take(&mut self.departs[idx]);
        for &g in &leaving {
            let id = g as usize;
            if self.engine.contains(id) {
                if self.engine.session_by_id(id).is_some_and(|s| s.active) {
                    self.active_now -= 1;
                }
                self.departed.push((id, self.engine.evict_session(id)));
            } else if let Some(cold) = self.cold.remove(&id) {
                let ColdSession { id, mut arena, metrics } = cold;
                arena.clear();
                self.arena_pool.push(arena);
                self.departed.push((id, metrics));
            } else {
                unreachable!("departing session {id} is neither resident nor cold");
            }
            self.shells.remove(&id);
            self.evictions += 1;
        }
        leaving.clear();
        self.departs[idx] = leaving;

        // 2. Sleeps: burst ends — hibernate (byte cost) or park idle.
        let mut bucket = take(&mut self.sleep_bucket[t % self.schedule.period]);
        bucket.retain(|&g| {
            let id = g as usize;
            if !self.schedule.plan(g).alive_at(t) {
                return false; // lazy purge of the departed
            }
            if self.engine.contains(id) {
                let was_active = self.engine.session_by_id(id).is_some_and(|s| s.active);
                if self.engine.can_hibernate(id) {
                    let arena = self.arena_pool.pop().unwrap_or_default();
                    let cold = self.engine.hibernate_session(id, arena);
                    self.cold.insert(id, cold);
                    self.hibernates += 1;
                } else {
                    self.engine.set_active(id, false);
                }
                if was_active {
                    self.active_now -= 1;
                }
            }
            true
        });
        self.sleep_bucket[t % self.schedule.period] = bucket;

        // 3. Wakes: burst starts — unpack from cold or flip back active.
        let mut bucket = take(&mut self.wake_bucket[t % self.schedule.period]);
        bucket.retain(|&g| {
            let id = g as usize;
            if !self.schedule.plan(g).alive_at(t) {
                return false;
            }
            if let Some(cold) = self.cold.remove(&id) {
                let shell = match self.shells.remove(&id) {
                    Some(shell) => shell,
                    None => {
                        let (policy, env, source) = (self.builder)(g);
                        Session::new(id, policy, env, source)
                    }
                };
                let arena = self.engine.wake_session(cold, shell);
                self.arena_pool.push(arena);
                self.active_now += 1;
                self.wakes += 1;
            } else {
                debug_assert!(self.engine.contains(id), "alive session {id} lost");
                if !self.engine.session_by_id(id).is_some_and(|s| s.active) {
                    self.engine.set_active(id, true);
                    self.active_now += 1;
                }
            }
            true
        });
        self.wake_bucket[t % self.schedule.period] = bucket;

        // 4. Arrivals: admit this boundary's cohort of fresh global ids.
        for g in self.schedule.arrivals_at(t) {
            self.admit(g, t);
        }
    }

    /// Pre-size everything the next `horizon` rounds touch — session
    /// shells for arrivals and wakes, spare cold arenas, bucket/ring/map
    /// capacity, engine membership and scratch envelopes — so churn
    /// rounds inside the horizon perform zero heap allocations (the
    /// hotpath bench's churn audit).  Idempotent; call again to extend.
    pub fn prepare(&mut self, horizon: usize) {
        let period = self.schedule.period;
        let ring_len = self.departs.len();

        // Arrival shells, and how many admissions the window holds.
        let mut due: Vec<u64> = Vec::new();
        for dt in 0..horizon {
            due.extend(self.schedule.arrivals_at(self.t + dt));
        }
        let arrivals = due.len();
        // Wake shells: every id in a wake bucket the window will visit
        // (cheap over-approximation — an unused shell is parked memory).
        for dt in 0..horizon.min(period) {
            due.extend(self.wake_bucket[(self.t + dt) % period].iter().copied());
        }
        for g in due {
            let id = g as usize;
            if !self.shells.contains_key(&id) && !self.engine.contains(id) {
                let plan = self.schedule.plan(g);
                let mut shell = self.build_session(g);
                // Enough record capacity for every burst the session can
                // ever serve, so admission-round metrics never regrow.
                let bursts = plan.lifespan.div_ceil(plan.period) + 1;
                shell.metrics.reserve(bursts * plan.on);
                self.shells.insert(id, shell);
            }
        }

        // Cold sessions waking inside the window resume pushing records;
        // their metrics buffers travel in the arena (outside the reach of
        // `Engine::reserve`), so pre-size them here.
        for dt in 0..horizon.min(period) {
            for &g in &self.wake_bucket[(self.t + dt) % period] {
                if let Some(c) = self.cold.get_mut(&(g as usize)) {
                    c.metrics.reserve(horizon);
                }
            }
        }

        // Transition envelopes inside the window.
        let sleeps: usize = (0..horizon.min(period))
            .map(|dt| self.sleep_bucket[(self.t + dt) % period].len())
            .sum();
        let wakes: usize = (0..horizon.min(period))
            .map(|dt| self.wake_bucket[(self.t + dt) % period].len())
            .sum();
        let departures: usize = (0..horizon.min(ring_len))
            .map(|dt| self.departs[(self.t + dt) % ring_len].len())
            .sum();

        // Spare arenas for every possible hibernation, pre-grown to a
        // generous multiple of the largest cold image seen so far.
        let est = self
            .cold
            .values()
            .map(|c| c.arena.len())
            .max()
            .unwrap_or(0)
            .max(1024)
            * 2;
        // Admissions can hibernate on arrival (off-duty admits), so the
        // arena/cold envelope covers them too.
        while self.arena_pool.len() < sleeps + arrivals {
            self.arena_pool.push(Vec::new());
        }
        for arena in &mut self.arena_pool {
            if arena.capacity() < est {
                arena.reserve(est - arena.len());
            }
        }
        // Waking sessions return arenas to the pool mid-window; sleeps
        // re-take them, but a wake-heavy boundary can push the pool past
        // its high-water mark — keep headroom so the push never regrows.
        self.arena_pool.reserve(wakes);

        self.cold.reserve(sleeps + arrivals);
        self.departed.reserve(departures);
        for b in self.wake_bucket.iter_mut().chain(self.sleep_bucket.iter_mut()) {
            b.reserve(arrivals + 1);
        }
        for slot in &mut self.departs {
            slot.reserve(arrivals + 1);
        }
        self.engine.reserve_sessions(arrivals + sleeps + 1);
        self.engine.reserve(horizon);
    }

    /// Run one round: apply this boundary's membership changes, then
    /// step the engine (select → submit → realize → observe).
    pub fn round(&mut self) {
        self.boundary(self.t);
        self.frames += self.active_now as u64;
        self.engine.step();
        self.t += 1;
    }

    /// Run `rounds` rounds.
    pub fn run(&mut self, rounds: usize) {
        for _ in 0..rounds {
            self.round();
        }
    }

    /// Fleet-state snapshot at the current boundary.
    pub fn stats(&self) -> OpenWorldStats {
        let resident = self.engine.num_sessions();
        OpenWorldStats {
            rounds: self.t,
            live: resident + self.cold.len(),
            resident,
            active: self.active_now,
            cold: self.cold.len(),
            cold_bytes: self.cold.values().map(|c| c.arena.len()).sum(),
            admissions: self.admissions,
            evictions: self.evictions,
            hibernates: self.hibernates,
            wakes: self.wakes,
            frames: self.frames,
        }
    }

    /// Borrow the underlying engine (trace draining, forecasts, …).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Mutably borrow the underlying engine.
    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    /// The driving schedule.
    pub fn schedule(&self) -> &ChurnSchedule {
        &self.schedule
    }

    /// Consume the fleet and return every session's metrics — departed,
    /// hibernated, and resident alike — sorted by global id (the
    /// canonical cross-run comparison order).
    pub fn into_metrics(mut self) -> Vec<(usize, Metrics)> {
        let mut out = self.departed;
        out.extend(self.cold.drain().map(|(id, c)| (id, c.metrics)));
        out.extend(self.engine.into_sessions().into_iter().map(|s| (s.id, s.metrics)));
        out.sort_unstable_by_key(|&(id, _)| id);
        out
    }
}

/// Assemble the open-world fleet a [`crate::config::Config`] with
/// `--arrivals > 0` describes: the closed-world
/// [`super::engine::fleet_from_config`] session family (same per-id
/// environments, policies, and video streams — session `g` here is
/// bit-identical to session `g` there), driven by a [`ChurnSchedule`]
/// built from `--sessions/--arrivals/--lifespan/--duty`.
pub fn openworld_from_config(cfg: &crate::config::Config) -> OpenWorld {
    let net = crate::models::zoo::by_name(&cfg.model).expect("validated model");
    let device = crate::simulator::profile_by_name(&cfg.device).expect("validated device");
    let edge = crate::simulator::profile_by_name(&cfg.edge).expect("validated edge");
    let schedule = ChurnSchedule::new(cfg.seed, cfg.sessions, cfg.arrivals, cfg.lifespan, cfg.duty);
    let ecfg = super::engine::engine_config_from(cfg);
    let cfg = cfg.clone();
    let builder: SessionBuilder = Box::new(move |g| {
        let env = crate::simulator::scenario::fleet_session(
            net.clone(),
            g,
            cfg.rate_mbps,
            device,
            edge,
            cfg.load,
            cfg.seed,
        );
        let policy = cfg.policy(&env.net, &env.device, &env.edge);
        let source = FrameSource::video(
            crate::util::rng::Rng::stream_seed(
                cfg.seed,
                super::engine::VIDEO_STREAM_BASE + g,
            ),
            cfg.ssim_threshold,
            crate::video::Weights::new(cfg.l_key, cfg.l_non_key),
        );
        (policy, env, source)
    });
    OpenWorld::new(ecfg, schedule, builder)
}
