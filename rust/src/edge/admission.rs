//! Admission-ordering policies for the shared edge queue.
//!
//! The edge server holds a bounded waiting room of offloaded ψ tensors
//! and, whenever the executor frees up, must pick which pending job (and
//! batch) to run next.  Three disciplines cover the fleet experiments:
//!
//! * [`AdmissionPolicy::Fifo`] — physical arrival order at the edge NIC.
//!   With batching off and an unbounded waiting room this is the PR 1
//!   lockstep degenerate case (the engine then skips the event queue
//!   entirely and reproduces the legacy rounds bit-identically).
//! * [`AdmissionPolicy::Edf`] — earliest deadline first.  Deadlines are
//!   anchored at frame *capture* time, so a session whose front/uplink
//!   legs already burned most of its budget arrives with little slack
//!   and jumps the queue: EDF compensates uplink heterogeneity with
//!   queue position, narrowing the fleet's delay spread.
//! * [`AdmissionPolicy::WeightedFair`] — longest weighted attained-wait
//!   first.  Each session accrues the queueing delay it has suffered so
//!   far; the job whose session has waited most (scaled by the frame
//!   weight L_t, so key frames count for more) is served next.  This is
//!   the rotation discipline: persistent positional bias, which FIFO
//!   locks in forever, is redistributed round over round.
//!
//! The policy only *orders* the waiting room; rejection (waiting room
//! full) happens at submit time in [`super::queue::EdgeQueue`] and sends
//! the frame back to on-device execution.

use super::queue::EdgeJob;

/// Pluggable ordering discipline for the edge waiting room.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Serve in NIC arrival order.
    Fifo,
    /// Earliest (capture-anchored) deadline first.
    Edf,
    /// Largest weighted accumulated queue-wait first.
    WeightedFair,
}

/// Policy names accepted by the CLI / config (`--scheduler ...`).
pub const SCHEDULER_NAMES: &[&str] = &["fifo", "edf", "wfair"];

impl AdmissionPolicy {
    /// Look a policy up by CLI/config name.
    pub fn by_name(name: &str) -> Option<AdmissionPolicy> {
        match name {
            "fifo" => Some(AdmissionPolicy::Fifo),
            "edf" => Some(AdmissionPolicy::Edf),
            "wfair" | "weighted-fair" | "wf" => Some(AdmissionPolicy::WeightedFair),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Fifo => "fifo",
            AdmissionPolicy::Edf => "edf",
            AdmissionPolicy::WeightedFair => "wfair",
        }
    }

    /// Index of the next job to dispatch among `waiting[..]` restricted
    /// to jobs that have arrived by `now_ms`.  `attained_wait_ms[s]` is
    /// session `s`'s accumulated queueing delay (the WeightedFair
    /// credit); sessions beyond the slice length count as zero.
    ///
    /// Ties always fall back to `(arrival, seq)`, so ordering *within* a
    /// priority class is FIFO — a property the queue's tests pin.
    pub fn select(
        &self,
        waiting: &[EdgeJob],
        now_ms: f64,
        attained_wait_ms: &[f64],
    ) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, job) in waiting.iter().enumerate() {
            if job.arrival_ms > now_ms {
                continue;
            }
            match best {
                None => best = Some(i),
                Some(b) => {
                    if self.beats(job, &waiting[b], now_ms, attained_wait_ms) {
                        best = Some(i);
                    }
                }
            }
        }
        best
    }

    /// Does `a` outrank `b` under this policy at time `now_ms`?
    fn beats(&self, a: &EdgeJob, b: &EdgeJob, now_ms: f64, attained_wait_ms: &[f64]) -> bool {
        let tie = |a: &EdgeJob, b: &EdgeJob| {
            a.arrival_ms
                .total_cmp(&b.arrival_ms)
                .then_with(|| a.seq.cmp(&b.seq))
                .is_lt()
        };
        match self {
            AdmissionPolicy::Fifo => tie(a, b),
            AdmissionPolicy::Edf => match a.deadline_ms.total_cmp(&b.deadline_ms) {
                std::cmp::Ordering::Less => true,
                std::cmp::Ordering::Greater => false,
                std::cmp::Ordering::Equal => tie(a, b),
            },
            AdmissionPolicy::WeightedFair => {
                let credit = |j: &EdgeJob| {
                    let acc = attained_wait_ms.get(j.session).copied().unwrap_or(0.0);
                    // Accrued wait plus this job's own age so far, scaled
                    // by frame importance: heavily weighted (key) frames
                    // of long-suffering sessions go first.
                    (acc + (now_ms - j.arrival_ms).max(0.0)) * j.weight.max(1e-12)
                };
                match credit(a).total_cmp(&credit(b)) {
                    std::cmp::Ordering::Greater => true,
                    std::cmp::Ordering::Less => false,
                    std::cmp::Ordering::Equal => tie(a, b),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(session: usize, arrival: f64, deadline: f64, weight: f64, seq: u64) -> EdgeJob {
        EdgeJob {
            session,
            p: 0,
            bytes: 1000,
            capture_ms: 0.0,
            arrival_ms: arrival,
            deadline_ms: deadline,
            weight,
            solo_ms: 5.0,
            seq,
        }
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(AdmissionPolicy::by_name("fifo"), Some(AdmissionPolicy::Fifo));
        assert_eq!(AdmissionPolicy::by_name("edf"), Some(AdmissionPolicy::Edf));
        assert_eq!(AdmissionPolicy::by_name("wfair"), Some(AdmissionPolicy::WeightedFair));
        assert_eq!(AdmissionPolicy::by_name("weighted-fair"), Some(AdmissionPolicy::WeightedFair));
        assert!(AdmissionPolicy::by_name("lifo").is_none());
        for n in SCHEDULER_NAMES {
            assert!(AdmissionPolicy::by_name(n).is_some(), "{n} must resolve");
        }
    }

    #[test]
    fn fifo_picks_earliest_arrival() {
        let w = vec![job(0, 3.0, 100.0, 0.2, 0), job(1, 1.0, 100.0, 0.2, 1)];
        assert_eq!(AdmissionPolicy::Fifo.select(&w, 10.0, &[]), Some(1));
    }

    #[test]
    fn unarrived_jobs_are_invisible() {
        let w = vec![job(0, 50.0, 60.0, 0.2, 0), job(1, 5.0, 200.0, 0.2, 1)];
        // At t=10 only job 1 has arrived, even though job 0's deadline wins.
        assert_eq!(AdmissionPolicy::Edf.select(&w, 10.0, &[]), Some(1));
        assert_eq!(AdmissionPolicy::Edf.select(&w, 55.0, &[]), Some(0));
        assert_eq!(AdmissionPolicy::Fifo.select(&w, 1.0, &[]), None);
    }

    #[test]
    fn edf_prefers_tight_deadline_then_fifo_within_class() {
        let w = vec![
            job(0, 1.0, 90.0, 0.2, 0),
            job(1, 2.0, 40.0, 0.2, 1),
            job(2, 3.0, 40.0, 0.2, 2),
        ];
        // Deadline 40 beats 90; within the 40-class, arrival order.
        assert_eq!(AdmissionPolicy::Edf.select(&w, 10.0, &[]), Some(1));
    }

    #[test]
    fn wfair_prefers_most_wronged_session() {
        let w = vec![job(0, 1.0, 100.0, 0.2, 0), job(1, 2.0, 100.0, 0.2, 1)];
        // Equal credit -> FIFO; session 1 with accrued wait jumps ahead.
        assert_eq!(AdmissionPolicy::WeightedFair.select(&w, 5.0, &[0.0, 0.0]), Some(0));
        assert_eq!(AdmissionPolicy::WeightedFair.select(&w, 5.0, &[0.0, 50.0]), Some(1));
    }

    #[test]
    fn wfair_weights_key_frames_up() {
        // Same accrued wait: the heavier (key) frame outranks.
        let w = vec![job(0, 1.0, 100.0, 0.2, 0), job(1, 1.5, 100.0, 0.8, 1)];
        assert_eq!(AdmissionPolicy::WeightedFair.select(&w, 11.0, &[10.0, 10.0]), Some(1));
    }
}
