//! Virtual time for the event-driven edge server.
//!
//! The scheduler is driven entirely by a logical clock (milliseconds on
//! the fleet's shared timeline), never wall time, so every schedule is
//! bit-reproducible.  [`VirtualClock`] is a monotone cursor ("when does
//! the executor free up"); [`EventQueue`] is a deterministic min-heap of
//! timestamped payloads (ties broken by submission sequence) used to
//! ingest offload arrivals in *time* order — the property that lets
//! sessions advance on independent clocks and still contend correctly.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Monotone virtual-time cursor in milliseconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now_ms: f64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { now_ms: 0.0 }
    }

    pub fn now_ms(&self) -> f64 {
        self.now_ms
    }

    /// Advance to `t_ms` (no-op if the clock is already past it) and
    /// return the new time.  Virtual clocks never run backwards.
    pub fn advance_to(&mut self, t_ms: f64) -> f64 {
        assert!(t_ms.is_finite(), "virtual time must be finite, got {t_ms}");
        if t_ms > self.now_ms {
            self.now_ms = t_ms;
        }
        self.now_ms
    }
}

/// One timestamped entry in the event queue.  `key` is the tie-break at
/// equal timestamps: the submission sequence number for [`EventQueue::push`]
/// (FIFO ties), or an explicit caller key for [`EventQueue::push_keyed`]
/// (the engine passes `(round, global session id)` so the cross-session
/// merge order is canonical — independent of iteration order — even when
/// open-world churn makes slot order diverge from id order).
#[derive(Debug, Clone)]
struct Event<T> {
    time_ms: f64,
    key: u64,
    payload: T,
}

impl<T> PartialEq for Event<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time_ms == other.time_ms && self.key == other.key
    }
}

impl<T> Eq for Event<T> {}

impl<T> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reverse so the earliest event (then
        // the lowest key) surfaces first.
        other
            .time_ms
            .total_cmp(&self.time_ms)
            .then_with(|| other.key.cmp(&self.key))
    }
}

/// Deterministic time-ordered queue: `pop` always yields the entry with
/// the smallest timestamp, ties resolved by insertion order.
#[derive(Debug, Clone)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Event<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Enqueue `payload` at `time_ms` (ties resolve FIFO by push order).
    pub fn push(&mut self, time_ms: f64, payload: T) {
        assert!(time_ms.is_finite(), "event time must be finite, got {time_ms}");
        self.heap.push(Event { time_ms, key: self.seq, payload });
        self.seq += 1;
    }

    /// Enqueue `payload` at `time_ms` with an explicit tie-break key:
    /// simultaneous events pop in ascending key order regardless of push
    /// order.  The engine passes `(round << 32) | global session id`, so
    /// the cross-session merge is canonical under open-world churn (where
    /// iteration order is slot order, not id order) and, within one
    /// round's closed-world pushes, identical to the FIFO tie-break the
    /// legacy transcripts pin.  Do not mix with [`EventQueue::push`] in
    /// the same queue — the key spaces are unrelated.
    pub fn push_keyed(&mut self, time_ms: f64, key: u64, payload: T) {
        assert!(time_ms.is_finite(), "event time must be finite, got {time_ms}");
        self.heap.push(Event { time_ms, key, payload });
    }

    /// Pre-size the heap for `n` additional events (zero-alloc rounds).
    pub fn reserve(&mut self, n: usize) {
        self.heap.reserve(n);
    }

    /// Timestamp of the earliest pending event.
    pub fn peek_time_ms(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time_ms)
    }

    /// Iterate over every pending payload in unspecified (heap) order —
    /// for order-insensitive aggregation such as the backlog work bound
    /// in [`crate::edge::forecast`].  The heap layout is a pure function
    /// of the push/pop history, so even this order is deterministic.
    pub fn payloads(&self) -> impl Iterator<Item = &T> {
        self.heap.iter().map(|e| &e.payload)
    }

    /// Remove and return the earliest event as `(time_ms, payload)`.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.time_ms, e.payload))
    }

    /// The internal submission-sequence counter (snapshot leg: future
    /// [`EventQueue::push`]es must keep numbering where the saved queue
    /// left off, or tie-break keys diverge after a restore).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Restore the submission-sequence counter saved by [`EventQueue::seq`].
    pub fn set_seq(&mut self, seq: u64) {
        self.seq = seq;
    }

    /// Every pending entry as `(time_ms, key, payload)` in canonical pop
    /// order — the snapshot encoding.  Re-inserting the entries in this
    /// order via [`EventQueue::push_keyed`] (then restoring the counter
    /// with [`EventQueue::set_seq`]) reproduces the pop sequence exactly:
    /// `(time, key)` pairs are unique per queue, so pop order — the only
    /// thing any consumer observes besides the order-insensitive
    /// [`EventQueue::payloads`] aggregation — is fully determined.
    pub fn entries_sorted(&self) -> Vec<(f64, u64, T)>
    where
        T: Clone,
    {
        let mut out: Vec<(f64, u64, T)> =
            self.heap.iter().map(|e| (e.time_ms, e.key, e.payload.clone())).collect();
        out.sort_by(|a, b| a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
        out
    }

    /// Drop every pending event while keeping the allocated capacity.
    /// (The engine's per-round merges drain via `pop` until empty and
    /// never need this; it exists for callers that must abandon a
    /// partially-consumed queue.)  The sequence counter keeps counting,
    /// so later pushes still order after anything pushed before the
    /// clear.
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotone() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now_ms(), 0.0);
        assert_eq!(c.advance_to(5.0), 5.0);
        assert_eq!(c.advance_to(3.0), 5.0, "clock must not run backwards");
        assert_eq!(c.advance_to(9.5), 9.5);
    }

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.len(), 3);
        assert_eq!(q.peek_time_ms(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn simultaneous_events_keep_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(7.0, i);
        }
        for i in 0..10 {
            assert_eq!(q.pop(), Some((7.0, i)), "ties must resolve FIFO");
        }
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn non_finite_event_time_rejected() {
        EventQueue::new().push(f64::NAN, ());
    }

    #[test]
    fn keyed_ties_resolve_by_key_not_push_order() {
        let mut q = EventQueue::new();
        // Push in reverse key order: the keys must still win the tie.
        for id in (0..10u64).rev() {
            q.push_keyed(7.0, id, id);
        }
        for id in 0..10 {
            assert_eq!(q.pop(), Some((7.0, id)), "ties must resolve by ascending key");
        }
        // Earlier timestamps still come first regardless of key.
        q.push_keyed(5.0, 100, 100);
        q.push_keyed(1.0, 900, 900);
        assert_eq!(q.pop(), Some((1.0, 900)));
        assert_eq!(q.pop(), Some((5.0, 100)));
    }

    #[test]
    fn entries_sorted_snapshot_reproduces_pop_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(1.0, "a2");
        q.push(2.0, "b");
        let entries = q.entries_sorted();
        let seq = q.seq();
        // Rebuild a twin from the snapshot legs.
        let mut twin = EventQueue::new();
        for (t, k, p) in entries {
            twin.push_keyed(t, k, p);
        }
        twin.set_seq(seq);
        // Identical pops, and identical tie-breaks on post-restore pushes.
        q.push(1.0, "late");
        twin.push(1.0, "late");
        loop {
            let (a, b) = (q.pop(), twin.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn clear_empties_but_keeps_ordering_semantics() {
        let mut q = EventQueue::new();
        q.push(5.0, "stale");
        q.clear();
        assert!(q.is_empty());
        // Post-clear pushes still order (time, then push order).
        q.push(2.0, "b");
        q.push(1.0, "a");
        q.push(2.0, "c");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((2.0, "c")));
    }
}
