//! Virtual time for the event-driven edge server.
//!
//! The scheduler is driven entirely by a logical clock (milliseconds on
//! the fleet's shared timeline), never wall time, so every schedule is
//! bit-reproducible.  [`VirtualClock`] is a monotone cursor ("when does
//! the executor free up"); [`EventQueue`] is a deterministic min-heap of
//! timestamped payloads (ties broken by submission sequence) used to
//! ingest offload arrivals in *time* order — the property that lets
//! sessions advance on independent clocks and still contend correctly.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Monotone virtual-time cursor in milliseconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now_ms: f64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { now_ms: 0.0 }
    }

    pub fn now_ms(&self) -> f64 {
        self.now_ms
    }

    /// Advance to `t_ms` (no-op if the clock is already past it) and
    /// return the new time.  Virtual clocks never run backwards.
    pub fn advance_to(&mut self, t_ms: f64) -> f64 {
        assert!(t_ms.is_finite(), "virtual time must be finite, got {t_ms}");
        if t_ms > self.now_ms {
            self.now_ms = t_ms;
        }
        self.now_ms
    }
}

/// One timestamped entry in the event queue.
#[derive(Debug, Clone)]
struct Event<T> {
    time_ms: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Event<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time_ms == other.time_ms && self.seq == other.seq
    }
}

impl<T> Eq for Event<T> {}

impl<T> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reverse so the earliest event (then
        // the lowest sequence number) surfaces first.
        other
            .time_ms
            .total_cmp(&self.time_ms)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic time-ordered queue: `pop` always yields the entry with
/// the smallest timestamp, ties resolved by insertion order.
#[derive(Debug, Clone)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Event<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Enqueue `payload` at `time_ms`.
    pub fn push(&mut self, time_ms: f64, payload: T) {
        assert!(time_ms.is_finite(), "event time must be finite, got {time_ms}");
        self.heap.push(Event { time_ms, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Timestamp of the earliest pending event.
    pub fn peek_time_ms(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time_ms)
    }

    /// Iterate over every pending payload in unspecified (heap) order —
    /// for order-insensitive aggregation such as the backlog work bound
    /// in [`crate::edge::forecast`].  The heap layout is a pure function
    /// of the push/pop history, so even this order is deterministic.
    pub fn payloads(&self) -> impl Iterator<Item = &T> {
        self.heap.iter().map(|e| &e.payload)
    }

    /// Remove and return the earliest event as `(time_ms, payload)`.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.time_ms, e.payload))
    }

    /// Drop every pending event while keeping the allocated capacity.
    /// (The engine's per-round merges drain via `pop` until empty and
    /// never need this; it exists for callers that must abandon a
    /// partially-consumed queue.)  The sequence counter keeps counting,
    /// so later pushes still order after anything pushed before the
    /// clear.
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotone() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now_ms(), 0.0);
        assert_eq!(c.advance_to(5.0), 5.0);
        assert_eq!(c.advance_to(3.0), 5.0, "clock must not run backwards");
        assert_eq!(c.advance_to(9.5), 9.5);
    }

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.len(), 3);
        assert_eq!(q.peek_time_ms(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn simultaneous_events_keep_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(7.0, i);
        }
        for i in 0..10 {
            assert_eq!(q.pop(), Some((7.0, i)), "ties must resolve FIFO");
        }
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn non_finite_event_time_rejected() {
        EventQueue::new().push(f64::NAN, ());
    }

    #[test]
    fn clear_empties_but_keeps_ordering_semantics() {
        let mut q = EventQueue::new();
        q.push(5.0, "stale");
        q.clear();
        assert!(q.is_empty());
        // Post-clear pushes still order (time, then push order).
        q.push(2.0, "b");
        q.push(1.0, "a");
        q.push(2.0, "c");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((2.0, "c")));
    }
}
