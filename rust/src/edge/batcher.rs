//! Cross-session batching at the edge executor.
//!
//! PR 1's pipeline micro-batcher coalesced one session's backlog; here
//! the *fleet's* concurrent ψ tensors at the same partition point fuse
//! into a single edge execution.  The service-time model is the crate's
//! [`Contention`] curve reinterpreted: where the lockstep engine
//! multiplies everyone's solo delay by `factor(k)` (k concurrent
//! offloaders), the event-driven edge runs one *shared* execution whose
//! cost is
//!
//! ```text
//! service(batch) = max_i(solo_i) · factor(b)        b = batch size
//!                = max_i(solo_i) · (1 + slope·max(0, b − capacity))
//! ```
//!
//! clamped to `Σ solo_i`: a batch can never cost more than serving its
//! members back to back (the amortization invariant, property-tested in
//! `tests/properties.rs`).  `capacity` is the executor's free
//! parallelism (batches up to it run at the single-frame cost), `slope`
//! the marginal cost per extra co-scheduled frame — the same two knobs,
//! now acting as the queue's service-time model instead of a static
//! multiplier.

use crate::simulator::Contention;

use super::admission::AdmissionPolicy;
use super::queue::EdgeJob;

/// Amortized service time (ms) of a batch with the given solo times.
pub fn batch_service_ms(solo_ms: &[f64], contention: &Contention) -> f64 {
    assert!(!solo_ms.is_empty(), "batch must have at least one member");
    let max = solo_ms.iter().fold(0.0_f64, |a, &b| a.max(b));
    let sum: f64 = solo_ms.iter().sum();
    (max * contention.factor(solo_ms.len())).min(sum)
}

/// Pick the members of the next batch from `waiting`, headed by
/// `waiting[head]`: jobs at the *same partition point* that have arrived
/// by `launch_ms`, in policy-priority order, up to `max_batch` members.
/// Returns indices into `waiting` (head first).
pub fn select_batch(
    waiting: &[EdgeJob],
    head: usize,
    launch_ms: f64,
    max_batch: usize,
    policy: &AdmissionPolicy,
    attained_wait_ms: &[f64],
) -> Vec<usize> {
    let mut members = Vec::new();
    let mut candidates = Vec::new();
    select_batch_into(
        waiting,
        head,
        launch_ms,
        max_batch,
        policy,
        attained_wait_ms,
        &mut members,
        &mut candidates,
    );
    members
}

/// [`select_batch`] into caller-provided buffers (`members` receives the
/// result, `candidates` is working space) — the allocation-free form the
/// queue's drain loop uses every launch.
#[allow(clippy::too_many_arguments)]
pub fn select_batch_into(
    waiting: &[EdgeJob],
    head: usize,
    launch_ms: f64,
    max_batch: usize,
    policy: &AdmissionPolicy,
    attained_wait_ms: &[f64],
    members: &mut Vec<usize>,
    candidates: &mut Vec<usize>,
) {
    assert!(head < waiting.len());
    members.clear();
    members.push(head);
    if max_batch <= 1 {
        return;
    }
    let p = waiting[head].p;
    // Candidates: same split point, arrived by launch, not the head.
    candidates.clear();
    for (i, j) in waiting.iter().enumerate() {
        if i != head && j.p == p && j.arrival_ms <= launch_ms {
            candidates.push(i);
        }
    }
    // Policy order among the candidates (repeated selection keeps the
    // implementation tiny; waiting rooms are fleet-sized, not huge).
    while members.len() < max_batch && !candidates.is_empty() {
        let mut best = 0;
        for c in 1..candidates.len() {
            let pool = [waiting[candidates[c]].clone(), waiting[candidates[best]].clone()];
            if policy.select(&pool, launch_ms, attained_wait_ms) == Some(0) {
                best = c;
            }
        }
        members.push(candidates.swap_remove(best));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(session: usize, p: usize, arrival: f64, solo: f64, seq: u64) -> EdgeJob {
        EdgeJob {
            session,
            p,
            bytes: 100,
            capture_ms: 0.0,
            arrival_ms: arrival,
            deadline_ms: f64::INFINITY,
            weight: 0.2,
            solo_ms: solo,
            seq,
        }
    }

    #[test]
    fn solo_batch_costs_solo_time() {
        let c = Contention::new(1, 0.25);
        assert_eq!(batch_service_ms(&[7.0], &c), 7.0);
    }

    #[test]
    fn batch_amortizes_but_never_beats_free() {
        let c = Contention::new(1, 0.25);
        // 4 frames at 8 ms solo: 8·(1 + 0.25·3) = 14 ms, far below 32.
        let s = batch_service_ms(&[8.0, 8.0, 8.0, 8.0], &c);
        assert!((s - 14.0).abs() < 1e-9, "{s}");
        // Capacity 4: the same batch rides free parallelism at solo cost.
        let free = batch_service_ms(&[8.0, 8.0, 8.0, 8.0], &Contention::new(4, 0.25));
        assert_eq!(free, 8.0);
    }

    #[test]
    fn pathological_slope_clamps_to_sum_of_solos() {
        // slope > 1 would make batching worse than serial: clamp.
        let c = Contention::new(1, 3.0);
        let s = batch_service_ms(&[5.0, 5.0, 5.0], &c);
        assert!((s - 15.0).abs() < 1e-9, "{s}");
    }

    #[test]
    fn batch_groups_same_partition_only() {
        let w = vec![
            job(0, 3, 1.0, 5.0, 0),
            job(1, 3, 2.0, 5.0, 1),
            job(2, 7, 2.5, 5.0, 2), // different split point: excluded
            job(3, 3, 3.0, 5.0, 3),
        ];
        let m = select_batch(&w, 0, 10.0, 8, &AdmissionPolicy::Fifo, &[]);
        assert_eq!(m, vec![0, 1, 3]);
    }

    #[test]
    fn batch_respects_max_and_arrival_cutoff() {
        let w = vec![
            job(0, 0, 1.0, 5.0, 0),
            job(1, 0, 2.0, 5.0, 1),
            job(2, 0, 99.0, 5.0, 2), // arrives after launch: excluded
            job(3, 0, 3.0, 5.0, 3),
        ];
        let m = select_batch(&w, 0, 10.0, 2, &AdmissionPolicy::Fifo, &[]);
        assert_eq!(m, vec![0, 1], "max_batch 2 takes head + first arrival");
        let solo_only = select_batch(&w, 0, 10.0, 1, &AdmissionPolicy::Fifo, &[]);
        assert_eq!(solo_only, vec![0]);
    }
}
