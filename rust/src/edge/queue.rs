//! The event-driven edge server queue.
//!
//! One executor (the edge GPU), one bounded waiting room, a virtual
//! clock.  Offloaded ψ tensors [`EdgeJob`]s arrive on the fleet's shared
//! timeline (capture + front + uplink + ingress), wait under an
//! [`AdmissionPolicy`], and run solo or as a cross-session batch whose
//! cost comes from the [`Contention`] service-time curve
//! (see [`super::batcher`]).  Offloads that find the waiting room full
//! are rejected at submit time and fall back to on-device execution —
//! the serving engine feeds that consequence to the session's bandit.
//!
//! Scheduling invariants (property-tested in `tests/properties.rs`):
//!
//! * **work conservation** — with batching off, the executor never
//!   idles while an arrived job waits (a batch window may hold the
//!   executor, but never longer than `batch_window_ms`);
//! * **FIFO within a priority class** — ties in any policy's key
//!   resolve by `(arrival, seq)`;
//! * **amortization** — a batch never costs more than serving its
//!   members back to back.

use crate::simulator::Contention;

use super::admission::AdmissionPolicy;
use super::batcher;
use super::clock::{EventQueue, VirtualClock};

/// One offloaded frame's ψ tensor, en route to the edge executor.
#[derive(Debug, Clone)]
pub struct EdgeJob {
    pub session: usize,
    /// Partition point — only same-p jobs batch together.
    pub p: usize,
    /// ψ_p payload size (diagnostics; the uplink/ingress legs are already
    /// folded into `arrival_ms`).
    pub bytes: usize,
    /// When the frame was captured on the device (deadline anchor).
    pub capture_ms: f64,
    /// When the tensor reaches the edge executor's waiting room.
    pub arrival_ms: f64,
    /// Absolute completion deadline (∞ = none): EDF's key.
    pub deadline_ms: f64,
    /// Frame weight L_t (key frames are heavier): WeightedFair's scale.
    pub weight: f64,
    /// Solo service time at the current exogenous edge load.
    pub solo_ms: f64,
    /// Submission sequence (assigned by the queue; final tie-break).
    pub seq: u64,
}

impl EdgeJob {
    /// Append every field to a snapshot arena (fixed-width, bit-exact).
    pub fn pack(&self, out: &mut Vec<u8>) {
        use crate::util::bytes::{put_f64, put_u64, put_usize};
        put_usize(out, self.session);
        put_usize(out, self.p);
        put_usize(out, self.bytes);
        put_f64(out, self.capture_ms);
        put_f64(out, self.arrival_ms);
        put_f64(out, self.deadline_ms);
        put_f64(out, self.weight);
        put_f64(out, self.solo_ms);
        put_u64(out, self.seq);
    }

    /// Read a job packed by [`EdgeJob::pack`].
    pub fn unpack(r: &mut crate::util::bytes::Reader<'_>) -> EdgeJob {
        EdgeJob {
            session: r.take_usize(),
            p: r.take_usize(),
            bytes: r.take_usize(),
            capture_ms: r.take_f64(),
            arrival_ms: r.take_f64(),
            deadline_ms: r.take_f64(),
            weight: r.take_f64(),
            solo_ms: r.take_f64(),
            seq: r.take_u64(),
        }
    }
}

/// One job's resolved schedule.
#[derive(Debug, Clone)]
pub struct Scheduled {
    pub session: usize,
    pub p: usize,
    pub seq: u64,
    /// When the job's batch launched on the executor.
    pub start_ms: f64,
    pub finish_ms: f64,
    /// `start − arrival`: time spent in the waiting room (plus any batch
    /// window the job sat through).
    pub queue_wait_ms: f64,
    /// Amortized execution time of the batch the job rode in.
    pub service_ms: f64,
    pub batch_size: usize,
}

/// Queue knobs (the engine derives these from [`crate::config::Config`]).
#[derive(Debug, Clone)]
pub struct QueueConfig {
    pub policy: AdmissionPolicy,
    /// How long a batch head may hold the executor waiting for co-riders
    /// (0 = only coalesce already-queued backlog).
    pub batch_window_ms: f64,
    /// Largest cross-session batch (1 = batching off).
    pub max_batch: usize,
    /// Waiting-room bound; arrivals beyond it are rejected
    /// (`usize::MAX` = unbounded).
    pub queue_capacity: usize,
    /// Service-time model for batches (see [`super::batcher`]).
    pub contention: Contention,
}

impl QueueConfig {
    pub fn new(policy: AdmissionPolicy, contention: Contention) -> QueueConfig {
        QueueConfig {
            policy,
            batch_window_ms: 0.0,
            max_batch: 1,
            queue_capacity: usize::MAX,
            contention,
        }
    }
}

/// Cumulative queue diagnostics.  Per-frame queue waits live in the
/// engine's [`crate::coordinator::metrics::FrameRecord`]s (which is
/// where `FleetSummary` computes its percentiles from); this struct
/// carries only what the records cannot: executor-side totals.
#[derive(Debug, Clone, Default)]
pub struct QueueStats {
    pub dispatched: usize,
    pub rejected: usize,
    pub batches: usize,
    /// Σ batch sizes over all launches (= `dispatched`).
    pub batched_jobs: usize,
    pub total_queue_wait_ms: f64,
    /// Total executor busy time — utilization when divided by the served
    /// horizon (`ans fleet` prints this line in event mode).
    pub busy_ms: f64,
}

impl QueueStats {
    pub fn mean_queue_wait_ms(&self) -> f64 {
        if self.dispatched == 0 {
            0.0
        } else {
            self.total_queue_wait_ms / self.dispatched as f64
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_jobs as f64 / self.batches as f64
        }
    }
}

/// The edge server's scheduling core (see module docs).
#[derive(Debug, Clone)]
pub struct EdgeQueue {
    pub cfg: QueueConfig,
    arrivals: EventQueue<EdgeJob>,
    waiting: Vec<EdgeJob>,
    /// Executor availability on the virtual timeline.
    clock: VirtualClock,
    /// Per-session accumulated queue wait (WeightedFair credit).
    attained_wait_ms: Vec<f64>,
    next_seq: u64,
    pub stats: QueueStats,
    /// Scratch buffers reused across launches so a steady-state drain
    /// performs no heap allocation (hotpath bench's alloc counter).
    scratch_members: Vec<usize>,
    scratch_candidates: Vec<usize>,
    scratch_solos: Vec<f64>,
    scratch_co_arrivals: Vec<f64>,
}

impl EdgeQueue {
    pub fn new(cfg: QueueConfig) -> EdgeQueue {
        assert!(cfg.max_batch >= 1, "max_batch must be ≥ 1");
        assert!(
            cfg.batch_window_ms >= 0.0 && cfg.batch_window_ms.is_finite(),
            "batch window must be finite and ≥ 0"
        );
        EdgeQueue {
            cfg,
            arrivals: EventQueue::new(),
            waiting: Vec::new(),
            clock: VirtualClock::new(),
            attained_wait_ms: Vec::new(),
            next_seq: 0,
            stats: QueueStats::default(),
            scratch_members: Vec::new(),
            scratch_candidates: Vec::new(),
            scratch_solos: Vec::new(),
            scratch_co_arrivals: Vec::new(),
        }
    }

    /// Jobs submitted but not yet dispatched.
    pub fn pending(&self) -> usize {
        self.arrivals.len() + self.waiting.len()
    }

    /// Is there room for one more job?
    pub fn has_room(&self) -> bool {
        self.pending() < self.cfg.queue_capacity
    }

    /// Virtual time at which the executor frees up.
    pub fn free_at_ms(&self) -> f64 {
        self.clock.now_ms()
    }

    /// Deterministic forecast of this queue's near-future behaviour
    /// (see [`super::forecast`]): executor-free time plus a serial work
    /// bound on any pending backlog, and the running batch statistics
    /// under the configured batching knobs.  Pure read — computing the
    /// forecast never perturbs the schedule — and allocation-free.
    pub fn forecast(&self) -> super::forecast::EdgeEstimate {
        let mut free = self.clock.now_ms();
        for job in &self.waiting {
            free += job.solo_ms;
        }
        for job in self.arrivals.payloads() {
            free += job.solo_ms;
        }
        super::forecast::EdgeEstimate::from_parts(
            free,
            self.pending(),
            self.stats.mean_batch_size(),
            self.cfg.max_batch,
            &self.cfg.contention,
        )
    }

    /// Append every mutable cursor of the queue to a snapshot arena:
    /// virtual clock, submission counters, per-session WeightedFair
    /// credits, executor stats, and both job buffers (the event heap in
    /// canonical sorted order — see [`EventQueue::entries_sorted`]).
    /// Between engine rounds both buffers are empty (every drain runs to
    /// exhaustion), but the encoding is total so property tests can
    /// round-trip mid-flight states too.
    pub fn pack_state(&self, out: &mut Vec<u8>) {
        use crate::util::bytes::{put_f64, put_f64s, put_u64, put_usize};
        put_f64(out, self.clock.now_ms());
        put_u64(out, self.next_seq);
        put_u64(out, self.arrivals.seq());
        put_f64s(out, &self.attained_wait_ms);
        put_usize(out, self.stats.dispatched);
        put_usize(out, self.stats.rejected);
        put_usize(out, self.stats.batches);
        put_usize(out, self.stats.batched_jobs);
        put_f64(out, self.stats.total_queue_wait_ms);
        put_f64(out, self.stats.busy_ms);
        let entries = self.arrivals.entries_sorted();
        put_usize(out, entries.len());
        for (time_ms, key, job) in &entries {
            put_f64(out, *time_ms);
            put_u64(out, *key);
            job.pack(out);
        }
        put_usize(out, self.waiting.len());
        for job in &self.waiting {
            job.pack(out);
        }
    }

    /// Restore state packed by [`EdgeQueue::pack_state`] into a
    /// config-identical freshly-built queue.
    pub fn unpack_state(&mut self, r: &mut crate::util::bytes::Reader<'_>) {
        self.clock.advance_to(r.take_f64());
        self.next_seq = r.take_u64();
        let arrivals_seq = r.take_u64();
        r.take_f64s_into(&mut self.attained_wait_ms);
        self.stats.dispatched = r.take_usize();
        self.stats.rejected = r.take_usize();
        self.stats.batches = r.take_usize();
        self.stats.batched_jobs = r.take_usize();
        self.stats.total_queue_wait_ms = r.take_f64();
        self.stats.busy_ms = r.take_f64();
        let n_arrivals = r.take_usize();
        for _ in 0..n_arrivals {
            let time_ms = r.take_f64();
            let key = r.take_u64();
            self.arrivals.push_keyed(time_ms, key, EdgeJob::unpack(r));
        }
        self.arrivals.set_seq(arrivals_seq);
        let n_waiting = r.take_usize();
        for _ in 0..n_waiting {
            self.waiting.push(EdgeJob::unpack(r));
        }
    }

    /// Submit a job; returns `false` (and counts a rejection) when the
    /// waiting room is full — the caller then serves the frame on-device.
    pub fn submit(&mut self, mut job: EdgeJob) -> bool {
        if !self.has_room() {
            self.stats.rejected += 1;
            return false;
        }
        job.seq = self.next_seq;
        self.next_seq += 1;
        self.arrivals.push(job.arrival_ms, job);
        true
    }

    /// Dispatch every pending job to completion on the virtual timeline
    /// and return the resolved schedules (in launch order).  Executor
    /// backlog persists across calls: a slow round delays the next one.
    pub fn drain(&mut self) -> Vec<Scheduled> {
        let mut out = Vec::new();
        self.drain_into(&mut out);
        out
    }

    /// [`EdgeQueue::drain`] into a caller-provided buffer (cleared
    /// first) — the allocation-free form the serving engine drives every
    /// round.  Identical schedule, byte for byte.
    pub fn drain_into(&mut self, out: &mut Vec<Scheduled>) {
        out.clear();
        while let Some((_, job)) = self.arrivals.pop() {
            self.waiting.push(job);
        }
        while !self.waiting.is_empty() {
            let earliest =
                self.waiting.iter().map(|j| j.arrival_ms).fold(f64::INFINITY, f64::min);
            // Work conservation: start as soon as both the executor and
            // at least one job are ready.
            let start = self.clock.now_ms().max(earliest);
            let head = self
                .cfg
                .policy
                .select(&self.waiting, start, &self.attained_wait_ms)
                .expect("some job has arrived by `start`");
            // A batch head may hold the executor for its window so
            // co-riders can join — but no longer than it takes to fill
            // the batch: once max_batch same-p tensors are on hand there
            // is nothing to wait for.  Solo dispatch launches at once.
            let launch = if self.cfg.max_batch > 1 {
                let window_close =
                    self.waiting[head].arrival_ms + self.cfg.batch_window_ms;
                let p = self.waiting[head].p;
                self.scratch_co_arrivals.clear();
                for (i, j) in self.waiting.iter().enumerate() {
                    if i != head && j.p == p {
                        self.scratch_co_arrivals.push(j.arrival_ms);
                    }
                }
                self.scratch_co_arrivals.sort_by(f64::total_cmp);
                let full_at = self
                    .scratch_co_arrivals
                    .get(self.cfg.max_batch - 2)
                    .copied()
                    .unwrap_or(f64::INFINITY);
                start.max(window_close.min(full_at))
            } else {
                start
            };
            batcher::select_batch_into(
                &self.waiting,
                head,
                launch,
                self.cfg.max_batch,
                &self.cfg.policy,
                &self.attained_wait_ms,
                &mut self.scratch_members,
                &mut self.scratch_candidates,
            );
            self.scratch_solos.clear();
            for &i in &self.scratch_members {
                self.scratch_solos.push(self.waiting[i].solo_ms);
            }
            let service = batcher::batch_service_ms(&self.scratch_solos, &self.cfg.contention);
            let finish = launch + service;
            let b = self.scratch_members.len();
            self.stats.batches += 1;
            self.stats.batched_jobs += b;
            self.stats.busy_ms += service;
            // Remove members back to front so indices stay valid.
            self.scratch_members.sort_unstable_by(|a, b| b.cmp(a));
            for &i in &self.scratch_members {
                let job = self.waiting.swap_remove(i);
                let wait = launch - job.arrival_ms;
                if self.attained_wait_ms.len() <= job.session {
                    self.attained_wait_ms.resize(job.session + 1, 0.0);
                }
                self.attained_wait_ms[job.session] += wait;
                self.stats.dispatched += 1;
                self.stats.total_queue_wait_ms += wait;
                out.push(Scheduled {
                    session: job.session,
                    p: job.p,
                    seq: job.seq,
                    start_ms: launch,
                    finish_ms: finish,
                    queue_wait_ms: wait,
                    service_ms: service,
                    batch_size: b,
                });
            }
            self.clock.advance_to(finish);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(policy: AdmissionPolicy) -> QueueConfig {
        QueueConfig::new(policy, Contention::new(1, 0.25))
    }

    fn job(session: usize, p: usize, arrival: f64, solo: f64) -> EdgeJob {
        EdgeJob {
            session,
            p,
            bytes: 100,
            capture_ms: 0.0,
            arrival_ms: arrival,
            deadline_ms: f64::INFINITY,
            weight: 0.2,
            solo_ms: solo,
            seq: 0,
        }
    }

    #[test]
    fn fifo_serves_in_arrival_order_with_queueing() {
        let mut q = EdgeQueue::new(cfg(AdmissionPolicy::Fifo));
        assert!(q.submit(job(0, 0, 10.0, 5.0)));
        assert!(q.submit(job(1, 0, 11.0, 5.0)));
        assert!(q.submit(job(2, 0, 30.0, 5.0)));
        let s = q.drain();
        assert_eq!(s.len(), 3);
        // Job 0: starts at its arrival, no wait.
        assert_eq!(s[0].session, 0);
        assert_eq!(s[0].start_ms, 10.0);
        assert_eq!(s[0].queue_wait_ms, 0.0);
        // Job 1: queues behind job 0 (15 − 11 = 4 ms).
        assert_eq!(s[1].session, 1);
        assert_eq!(s[1].start_ms, 15.0);
        assert!((s[1].queue_wait_ms - 4.0).abs() < 1e-9);
        // Job 2: executor idle again by 30 — no wait.
        assert_eq!(s[2].session, 2);
        assert_eq!(s[2].start_ms, 30.0);
        assert_eq!(s[2].queue_wait_ms, 0.0);
        assert_eq!(q.stats.dispatched, 3);
        assert_eq!(q.stats.rejected, 0);
        assert!(q.stats.mean_queue_wait_ms() > 0.0);
    }

    #[test]
    fn backlog_persists_across_drains() {
        let mut q = EdgeQueue::new(cfg(AdmissionPolicy::Fifo));
        q.submit(job(0, 0, 0.0, 100.0));
        let first = q.drain();
        assert_eq!(first[0].finish_ms, 100.0);
        // Next round's job arrives at 10 but the executor is busy to 100.
        q.submit(job(1, 0, 10.0, 5.0));
        let second = q.drain();
        assert_eq!(second[0].start_ms, 100.0);
        assert!((second[0].queue_wait_ms - 90.0).abs() < 1e-9);
    }

    #[test]
    fn full_waiting_room_rejects() {
        let mut c = cfg(AdmissionPolicy::Fifo);
        c.queue_capacity = 2;
        let mut q = EdgeQueue::new(c);
        assert!(q.submit(job(0, 0, 0.0, 5.0)));
        assert!(q.submit(job(1, 0, 0.0, 5.0)));
        assert!(!q.submit(job(2, 0, 0.0, 5.0)), "third job must bounce");
        assert_eq!(q.stats.rejected, 1);
        assert_eq!(q.drain().len(), 2);
        // Room frees after the drain.
        assert!(q.submit(job(3, 0, 0.0, 5.0)));
    }

    #[test]
    fn same_split_jobs_batch_and_finish_together() {
        let mut c = cfg(AdmissionPolicy::Fifo);
        c.max_batch = 4;
        c.batch_window_ms = 10.0;
        let mut q = EdgeQueue::new(c);
        for s in 0..4 {
            q.submit(job(s, 2, s as f64, 8.0));
        }
        let out = q.drain();
        assert_eq!(out.len(), 4);
        // The batch is full once the 4th tensor lands at t=3: launch then,
        // not at the window close (t=10); factor(4) = 1.75.
        let finish = out[0].finish_ms;
        assert!((finish - 17.0).abs() < 1e-9, "launch 3 + 8·1.75 = 17, got {finish}");
        for s in &out {
            assert_eq!(s.batch_size, 4);
            assert_eq!(s.finish_ms, finish, "batch members share a completion time");
        }
        assert!((q.stats.mean_batch_size() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn partial_batch_waits_out_the_window_only() {
        // Only 2 of max 4 tensors show up: the head holds for its full
        // window, then launches with whoever arrived.
        let mut c = cfg(AdmissionPolicy::Fifo);
        c.max_batch = 4;
        c.batch_window_ms = 10.0;
        let mut q = EdgeQueue::new(c);
        q.submit(job(0, 2, 0.0, 8.0));
        q.submit(job(1, 2, 1.0, 8.0));
        let out = q.drain();
        assert_eq!(out.len(), 2);
        // Launch at window close 10; factor(2) = 1.25 → finish 20.
        assert!((out[0].start_ms - 10.0).abs() < 1e-9, "{}", out[0].start_ms);
        assert!((out[0].finish_ms - 20.0).abs() < 1e-9, "{}", out[0].finish_ms);
        assert_eq!(out[0].batch_size, 2);
    }

    #[test]
    fn wfair_rotates_the_unlucky_session_forward() {
        // Two sessions collide every round; under FIFO session 1 always
        // queues behind session 0.  WeightedFair alternates.
        let run = |policy| {
            let mut q = EdgeQueue::new(cfg(policy));
            let mut waits = [0.0, 0.0];
            for round in 0..10 {
                let t = round as f64 * 100.0;
                q.submit(job(0, 0, t, 5.0));
                q.submit(job(1, 0, t, 5.0));
                for s in q.drain() {
                    waits[s.session] += s.queue_wait_ms;
                }
            }
            waits
        };
        let fifo = run(AdmissionPolicy::Fifo);
        assert_eq!(fifo[0], 0.0, "FIFO: session 0 never waits");
        assert!((fifo[1] - 50.0).abs() < 1e-9, "FIFO: session 1 always waits 5 ms");
        let wf = run(AdmissionPolicy::WeightedFair);
        assert!(wf[0] > 0.0 && wf[1] > 0.0, "wfair shares the pain: {wf:?}");
        assert!(
            (wf[0] - wf[1]).abs() <= 5.0 + 1e-9,
            "wfair waits stay within one service of each other: {wf:?}"
        );
    }

    #[test]
    fn pack_state_round_trips_a_mid_flight_queue_bit_exactly() {
        let mut c = cfg(AdmissionPolicy::WeightedFair);
        c.max_batch = 4;
        c.batch_window_ms = 6.0;
        let mut q = EdgeQueue::new(c.clone());
        // Build up history: dispatched work, credits, and a live backlog.
        for s in 0..4 {
            q.submit(job(s, 1, s as f64, 7.0));
        }
        let _ = q.drain();
        for s in 0..3 {
            q.submit(job(s, 2, 100.0 + s as f64, 5.0));
        }
        let mut blob = Vec::new();
        q.pack_state(&mut blob);
        let mut twin = EdgeQueue::new(c);
        twin.unpack_state(&mut crate::util::bytes::Reader::new(&blob));
        // Double-encode is byte-stable (canonical heap encoding).
        let mut blob2 = Vec::new();
        twin.pack_state(&mut blob2);
        assert_eq!(blob, blob2, "snapshot encoding must be canonical");
        // Both queues serve the backlog and later submissions identically.
        q.submit(job(9, 2, 104.0, 5.0));
        twin.submit(job(9, 2, 104.0, 5.0));
        let a = q.drain();
        let b = twin.drain();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.session, y.session);
            assert_eq!(x.seq, y.seq);
            assert_eq!(x.start_ms.to_bits(), y.start_ms.to_bits());
            assert_eq!(x.finish_ms.to_bits(), y.finish_ms.to_bits());
            assert_eq!(x.queue_wait_ms.to_bits(), y.queue_wait_ms.to_bits());
            assert_eq!(x.batch_size, y.batch_size);
        }
        assert_eq!(q.stats.dispatched, twin.stats.dispatched);
        assert_eq!(q.stats.busy_ms.to_bits(), twin.stats.busy_ms.to_bits());
    }

    #[test]
    fn edf_jumps_the_tight_deadline_ahead() {
        let mut q = EdgeQueue::new(cfg(AdmissionPolicy::Edf));
        // Busy the executor so both contenders queue.
        q.submit(job(9, 0, 0.0, 10.0));
        let mut loose = job(0, 0, 1.0, 5.0);
        loose.deadline_ms = 500.0;
        let mut tight = job(1, 0, 2.0, 5.0);
        tight.deadline_ms = 20.0;
        q.submit(loose);
        q.submit(tight);
        let out = q.drain();
        assert_eq!(out[0].session, 9);
        assert_eq!(out[1].session, 1, "tight deadline overtakes earlier arrival");
        assert_eq!(out[2].session, 0);
    }
}
