//! Deterministic queue-state forecasting for the select phase.
//!
//! Since PR 2 the realize phase runs a real event-driven edge queue, but
//! the select phase still decided against the lockstep
//! `Contention::factor(k)` expected-delay model — the policies were
//! blind to the very dynamics they are supposed to adapt to.  This
//! module closes that loop (ROADMAP: "Close the select-phase loop on
//! the event queue"): an [`EdgeEstimate`] is computed **once per round,
//! on the main thread, before any of the round's offloads submit**,
//! from nothing but the live [`super::queue::EdgeQueue`] state — the
//! virtual-clock time at which the executor frees up, the pending
//! backlog's serial work bound, and the queue's running batch-size
//! statistics.  Every quantity is a pure function of the queue's
//! deterministic history, so the estimate is bit-identical at every
//! worker count and across reruns (DESIGN.md §9).
//!
//! What the estimate predicts, per candidate partition p of one session:
//!
//! ```text
//! arrival_p  = capture + d_p^f + tx(ψ_p)        (session-local, known)
//! wait_p     = max(0, free_at − arrival_p)      [EdgeEstimate::wait_ms]
//! service_p  = solo_p · min(factor(b̂), b̂)       [EdgeEstimate::service_ms]
//! d̂_p^e      = tx(ψ_p) + wait_p + service_p
//! ```
//!
//! where `b̂` is the expected cross-session batch size (the queue's
//! running mean, clamped to `[1, max_batch]`) and `factor` is the
//! [`Contention`] service-time curve — the same two knobs the batcher
//! itself runs on, reused as a forecast instead of a lockstep
//! multiplier.  The model deliberately ignores *same-round* co-arrivals
//! (they are unknowable before everyone has selected); DESIGN.md §9
//! discusses that residual.
//!
//! [`QueueSignal`] picks how much of the estimate the select phase
//! exposes: `off` (legacy lockstep context, pinned bit-identical),
//! `wait` (predicted wait as a known per-arm delay), `full` (wait plus
//! the widened μLinUCB context dimensions — see
//! [`crate::models::features`]).

use crate::simulator::Contention;

/// Deterministic per-session phase offset in [0, 1) for the herding
/// stagger (`--signal-stagger`; DESIGN.md §10): the golden-ratio
/// low-discrepancy sequence, so any contiguous block of session ids
/// spreads near-uniformly over the unit interval and no two small ids
/// share an offset.  Session 0 maps to exactly 0.0 — a lone session
/// never sees a shifted signal, and a stagger of 0 ms adds exactly
/// +0.0 to every published wait (the no-stagger transcripts stay
/// bit-identical).  The offset perturbs only what the select phase
/// *publishes*; realized waits and the event-clock oracle never see it.
pub fn signal_phase(session: usize) -> f64 {
    const PHI_CONJ: f64 = 0.618_033_988_749_894_9;
    (session as f64 * PHI_CONJ).fract()
}

/// How much queue state the select phase exposes to the policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueSignal {
    /// Legacy lockstep context: policies select under
    /// `Contention::factor(k)` exactly as before the forecast existed
    /// (bit-identical to the PR 2/3 transcripts, pinned in tests).
    #[default]
    Off,
    /// The per-arm predicted wait is exposed as a *known* additive
    /// delay: μLinUCB folds it into the known part of its score (and
    /// learns on wait-stripped feedback), Neurosurgeon adds it to its
    /// layer-wise totals, and the privileged expected totals are the
    /// queue-aware forecasts.
    Wait,
    /// [`QueueSignal::Wait`] plus the widened learner context: the
    /// batch-merge and service-inflation features
    /// ([`crate::models::features::QUEUE_MERGE_FEATURE`] /
    /// [`crate::models::features::QUEUE_LOAD_FEATURE`]) are written
    /// into every off-device arm's context vector, so μLinUCB regresses
    /// the residual queue-correlated service structure.
    Full,
}

/// Names accepted by `--queue-signal` (CLI / config).
pub const QUEUE_SIGNAL_NAMES: &[&str] = &["off", "wait", "full"];

impl QueueSignal {
    /// Look a signal mode up by CLI/config name.
    pub fn by_name(name: &str) -> Option<QueueSignal> {
        match name {
            "off" => Some(QueueSignal::Off),
            "wait" => Some(QueueSignal::Wait),
            "full" => Some(QueueSignal::Full),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            QueueSignal::Off => "off",
            QueueSignal::Wait => "wait",
            QueueSignal::Full => "full",
        }
    }

    pub fn is_off(&self) -> bool {
        *self == QueueSignal::Off
    }
}

/// A frozen, deterministic snapshot of the edge queue's expected
/// behaviour, taken before a round's offloads submit (see module docs).
/// `Copy`, so the sharded select workers all read the same bits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EdgeEstimate {
    /// Virtual time at which the executor frees up, including a serial
    /// (policy-agnostic) work bound on any still-pending backlog.
    pub free_at_ms: f64,
    /// Jobs submitted but not yet dispatched at forecast time (0 in the
    /// engine's steady state, where every round drains fully).
    pub backlog: usize,
    /// Expected cross-session batch size b̂: the queue's running mean
    /// batch size, clamped to `[1, max_batch]`; exactly 1 with batching
    /// off or before any batch launched.
    pub expected_batch: f64,
    /// Expected per-member service multiplier of a b̂-sized batch:
    /// `min(factor(b̂), b̂)` — the batcher's amortization curve evaluated
    /// at the expected size (1.0 = solo cost).
    pub amortization: f64,
    /// Probability proxy that an offload shares its execution with at
    /// least one co-rider: `(b̂ − 1) / (max_batch − 1)`, clamped to
    /// [0, 1]; 0 with batching off.
    pub merge_probability: f64,
}

impl EdgeEstimate {
    /// The empty idle queue: zero wait at any arrival, solo service.
    pub fn idle() -> EdgeEstimate {
        EdgeEstimate {
            free_at_ms: 0.0,
            backlog: 0,
            expected_batch: 1.0,
            amortization: 1.0,
            merge_probability: 0.0,
        }
    }

    /// Assemble an estimate from raw queue observables (the
    /// [`super::queue::EdgeQueue::forecast`] entry point).
    pub fn from_parts(
        free_at_ms: f64,
        backlog: usize,
        mean_batch: f64,
        max_batch: usize,
        contention: &Contention,
    ) -> EdgeEstimate {
        let expected_batch = if max_batch <= 1 {
            1.0
        } else {
            mean_batch.clamp(1.0, max_batch as f64)
        };
        // factor_f ≥ 1 and expected_batch ≥ 1, so the min stays ≥ 1.
        let amortization = contention.factor_f(expected_batch).min(expected_batch);
        let merge_probability = if max_batch <= 1 {
            0.0
        } else {
            ((expected_batch - 1.0) / (max_batch as f64 - 1.0)).clamp(0.0, 1.0)
        };
        EdgeEstimate { free_at_ms, backlog, expected_batch, amortization, merge_probability }
    }

    /// Predicted waiting-room delay for a ψ tensor arriving at
    /// `arrival_ms`: how long until the executor frees up.  Zero for an
    /// idle queue — and monotone in the backlog behind `free_at_ms`
    /// (property-tested in `tests/properties.rs`).
    pub fn wait_ms(&self, arrival_ms: f64) -> f64 {
        (self.free_at_ms - arrival_ms).max(0.0)
    }

    /// Predicted execution time of a job with the given solo service
    /// time, amortized over the expected batch.
    pub fn service_ms(&self, solo_ms: f64) -> f64 {
        solo_ms * self.amortization
    }

    /// Predicted edge-offloading delay d̂_p^e for one candidate arm:
    /// uplink tx + predicted wait (at `arrival_ms = capture + front +
    /// tx`) + amortized service.
    pub fn edge_delay_ms(&self, tx_ms: f64, arrival_ms: f64, solo_ms: f64) -> f64 {
        tx_ms + self.wait_ms(arrival_ms) + self.service_ms(solo_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signal_phase_is_a_low_discrepancy_unit_offset() {
        assert_eq!(signal_phase(0), 0.0, "session 0 is never shifted");
        let mut seen = Vec::new();
        for i in 0..16 {
            let p = signal_phase(i);
            assert!((0.0..1.0).contains(&p), "phase {p} out of [0,1)");
            assert!(
                seen.iter().all(|&q: &f64| (q - p).abs() > 1e-9),
                "phases must be pairwise distinct for small ids"
            );
            seen.push(p);
        }
        // Deterministic: same id, same bits.
        assert_eq!(signal_phase(7), signal_phase(7));
    }

    #[test]
    fn signal_names_round_trip() {
        for n in QUEUE_SIGNAL_NAMES {
            let s = QueueSignal::by_name(n).expect("listed name must resolve");
            assert_eq!(s.name(), *n);
        }
        assert!(QueueSignal::by_name("half").is_none());
        assert!(QueueSignal::Off.is_off());
        assert!(!QueueSignal::Full.is_off());
        assert_eq!(QueueSignal::default(), QueueSignal::Off);
    }

    #[test]
    fn idle_estimate_predicts_nothing() {
        let e = EdgeEstimate::idle();
        assert_eq!(e.wait_ms(0.0), 0.0);
        assert_eq!(e.wait_ms(123.4), 0.0);
        assert_eq!(e.service_ms(7.0), 7.0);
        assert_eq!(e.edge_delay_ms(3.0, 50.0, 7.0), 10.0);
        assert_eq!(e.merge_probability, 0.0);
    }

    #[test]
    fn wait_is_the_gap_to_free_time() {
        let c = Contention::new(1, 0.25);
        let e = EdgeEstimate::from_parts(100.0, 3, 1.0, 1, &c);
        assert_eq!(e.wait_ms(40.0), 60.0);
        assert_eq!(e.wait_ms(100.0), 0.0);
        assert_eq!(e.wait_ms(140.0), 0.0, "late arrivals never wait");
    }

    #[test]
    fn amortization_follows_the_contention_curve() {
        let c = Contention::new(1, 0.25);
        // b̂ = 4 → factor 1.75, well below the serial bound of 4.
        let e = EdgeEstimate::from_parts(0.0, 0, 4.0, 8, &c);
        assert!((e.amortization - 1.75).abs() < 1e-12);
        assert!((e.service_ms(8.0) - 14.0).abs() < 1e-12);
        assert!((e.merge_probability - 3.0 / 7.0).abs() < 1e-12);
        // Pathological slope clamps to the serial bound.
        let steep = EdgeEstimate::from_parts(0.0, 0, 3.0, 8, &Contention::new(1, 3.0));
        assert!((steep.amortization - 3.0).abs() < 1e-12);
        // Capacity soaks the whole batch: solo cost.
        let free = EdgeEstimate::from_parts(0.0, 0, 4.0, 8, &Contention::new(8, 0.5));
        assert_eq!(free.amortization, 1.0);
    }

    #[test]
    fn batching_off_pins_the_batch_features() {
        let c = Contention::new(1, 0.5);
        let e = EdgeEstimate::from_parts(10.0, 1, 6.5, 1, &c);
        assert_eq!(e.expected_batch, 1.0);
        assert_eq!(e.amortization, 1.0);
        assert_eq!(e.merge_probability, 0.0);
    }

    #[test]
    fn mean_batch_is_clamped_to_the_configured_maximum() {
        let c = Contention::new(1, 0.25);
        let e = EdgeEstimate::from_parts(0.0, 0, 40.0, 4, &c);
        assert_eq!(e.expected_batch, 4.0);
        assert_eq!(e.merge_probability, 1.0);
        let cold = EdgeEstimate::from_parts(0.0, 0, 0.0, 4, &c);
        assert_eq!(cold.expected_batch, 1.0, "no history yet → solo expectation");
    }
}
