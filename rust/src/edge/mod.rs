//! The edge-server scheduler subsystem (DESIGN.md §7).
//!
//! PR 1's engine modelled the shared edge as a per-round multiplicative
//! slowdown (`Contention::factor(k_t)` applied to every offloader's
//! compute).  This module replaces that with a real server: an
//! event-driven queue on a virtual clock ([`clock`], [`queue`]), a
//! cross-session batcher whose amortization curve *is* the `Contention`
//! model ([`batcher`]), and pluggable admission disciplines with
//! on-device fallback for rejected offloads ([`admission`]).
//!
//! The old behaviour stays reachable: [`SchedulerConfig::is_lockstep`]
//! (FIFO, batching off, unbounded waiting room, no staggering) makes the
//! engine skip this subsystem entirely and run the PR 1 rounds, pinned
//! bit-identical in `rust/tests/fleet.rs`.

pub mod admission;
pub mod batcher;
pub mod clock;
pub mod forecast;
pub mod queue;

pub use admission::{AdmissionPolicy, SCHEDULER_NAMES};
pub use clock::{EventQueue, VirtualClock};
pub use forecast::{signal_phase, EdgeEstimate, QueueSignal, QUEUE_SIGNAL_NAMES};
pub use queue::{EdgeJob, EdgeQueue, QueueConfig, QueueStats, Scheduled};

use crate::simulator::Contention;

/// Engine-facing scheduler knobs (derived from CLI/config by
/// [`crate::config::Config::scheduler_config`]).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub policy: AdmissionPolicy,
    /// How long a batch head holds the executor for co-riders.
    pub batch_window_ms: f64,
    /// Largest cross-session batch (1 = batching off).
    pub max_batch: usize,
    /// Edge waiting-room bound (`usize::MAX` = unbounded; smaller values
    /// reject excess offloads back to on-device execution).
    pub queue_capacity: usize,
    /// Per-frame completion budget, anchored at capture time (EDF's key;
    /// `f64::INFINITY` = no deadline).
    pub deadline_ms: f64,
    /// Per-session capture-clock offset: session `i` captures frame `t`
    /// at `t·interval + i·stagger` — sessions advance on independent
    /// clocks and only offloads that overlap in *time* contend.
    pub stagger_ms: f64,
    /// Run the event queue even for the plain-FIFO configuration (which
    /// would otherwise take the lockstep fast path).
    pub force_event: bool,
}

impl SchedulerConfig {
    /// The PR 1 degenerate case: FIFO, no batching, nothing rejected,
    /// shared lockstep clock.  [`crate::coordinator::engine::Engine`]
    /// reproduces the legacy rounds bit-identically under this config.
    pub fn lockstep_fifo() -> SchedulerConfig {
        SchedulerConfig {
            policy: AdmissionPolicy::Fifo,
            batch_window_ms: 0.0,
            max_batch: 1,
            queue_capacity: usize::MAX,
            deadline_ms: f64::INFINITY,
            stagger_ms: 0.0,
            force_event: false,
        }
    }

    /// An event-driven scheduler under `policy` with batching enabled
    /// (window 8 ms, batches up to 8 — the fleet-serving defaults).
    pub fn event(policy: AdmissionPolicy) -> SchedulerConfig {
        SchedulerConfig {
            policy,
            batch_window_ms: 8.0,
            max_batch: 8,
            queue_capacity: usize::MAX,
            deadline_ms: 50.0,
            stagger_ms: 0.0,
            force_event: true,
        }
    }

    /// Does this configuration degenerate to the PR 1 lockstep rounds?
    pub fn is_lockstep(&self) -> bool {
        self.policy == AdmissionPolicy::Fifo
            && self.max_batch <= 1
            && self.batch_window_ms == 0.0
            && self.queue_capacity == usize::MAX
            && self.stagger_ms == 0.0
            && !self.force_event
    }
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig::lockstep_fifo()
    }
}

/// What the scheduler did with one offload request.
#[derive(Debug, Clone, Copy)]
pub enum Outcome {
    /// Ran at the edge: total waiting-room delay, amortized execution
    /// time, and the batch it rode in.
    Served { queue_wait_ms: f64, service_ms: f64, batch_size: usize },
    /// Waiting room full: the device completes the back-end locally.
    Rejected,
}

/// The engine's handle on the event-driven edge server: wraps an
/// [`EdgeQueue`] and maps per-round offload requests to [`Outcome`]s.
#[derive(Debug, Clone)]
pub struct EdgeScheduler {
    pub cfg: SchedulerConfig,
    queue: EdgeQueue,
}

impl EdgeScheduler {
    pub fn new(cfg: SchedulerConfig, contention: Contention) -> EdgeScheduler {
        let mut qc = QueueConfig::new(cfg.policy, contention);
        qc.batch_window_ms = cfg.batch_window_ms;
        qc.max_batch = cfg.max_batch;
        qc.queue_capacity = cfg.queue_capacity;
        EdgeScheduler { queue: EdgeQueue::new(qc), cfg }
    }

    /// Is there room for one more offload right now?  (The engine checks
    /// before spending shared-ingress bandwidth on the payload.)
    pub fn has_room(&self) -> bool {
        self.queue.has_room()
    }

    /// Submit one offload; `false` = rejected (fall back on-device).
    pub fn submit(&mut self, job: EdgeJob) -> bool {
        self.queue.submit(job)
    }

    /// Count a rejection decided before submit (the engine checks
    /// [`EdgeScheduler::has_room`] *before* spending shared-ingress
    /// bandwidth on a doomed payload).
    pub fn note_rejected(&mut self) {
        self.queue.stats.rejected += 1;
    }

    /// Resolve every pending offload on the virtual timeline; returns
    /// `(session, Outcome)` pairs in launch order.  Executor backlog
    /// carries over to the next round.
    pub fn drain(&mut self) -> Vec<(usize, Outcome)> {
        self.queue
            .drain()
            .into_iter()
            .map(|s| {
                (
                    s.session,
                    Outcome::Served {
                        queue_wait_ms: s.queue_wait_ms,
                        service_ms: s.service_ms,
                        batch_size: s.batch_size,
                    },
                )
            })
            .collect()
    }

    /// [`EdgeScheduler::drain`] into a caller-provided buffer of raw
    /// [`Scheduled`] entries (cleared first) — the allocation-free form
    /// the serving engine drives every round.
    pub fn drain_scheduled_into(&mut self, out: &mut Vec<Scheduled>) {
        self.queue.drain_into(out);
    }

    /// Deterministic pre-round forecast of the queue's behaviour — the
    /// select phase's [`EdgeEstimate`] (see [`forecast`]).
    pub fn forecast(&self) -> EdgeEstimate {
        self.queue.forecast()
    }

    pub fn stats(&self) -> &QueueStats {
        &self.queue.stats
    }

    /// Jobs currently sitting in the waiting room (between rounds this
    /// is the backlog the next forecast publishes).
    pub fn pending(&self) -> usize {
        self.queue.pending()
    }

    /// Virtual-clock time at which the executor frees up — the
    /// `queue_drain` trace event's clock stamp.
    pub fn free_at_ms(&self) -> f64 {
        self.queue.free_at_ms()
    }

    /// Append the scheduler's mutable state to a snapshot arena (see
    /// [`EdgeQueue::pack_state`]; the config half is rebuilt from
    /// [`crate::config::Config`] on restore).
    pub fn pack_state(&self, out: &mut Vec<u8>) {
        self.queue.pack_state(out);
    }

    /// Restore state packed by [`EdgeScheduler::pack_state`] into a
    /// config-identical freshly-built scheduler.
    pub fn unpack_state(&mut self, r: &mut crate::util::bytes::Reader<'_>) {
        self.queue.unpack_state(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lockstep_detection() {
        assert!(SchedulerConfig::lockstep_fifo().is_lockstep());
        assert!(SchedulerConfig::default().is_lockstep());
        assert!(!SchedulerConfig::event(AdmissionPolicy::Fifo).is_lockstep());
        assert!(!SchedulerConfig::event(AdmissionPolicy::Edf).is_lockstep());
        let mut batched = SchedulerConfig::lockstep_fifo();
        batched.max_batch = 4;
        assert!(!batched.is_lockstep(), "batching leaves the lockstep path");
        let mut bounded = SchedulerConfig::lockstep_fifo();
        bounded.queue_capacity = 8;
        assert!(!bounded.is_lockstep(), "admission control leaves the lockstep path");
        let mut forced = SchedulerConfig::lockstep_fifo();
        forced.force_event = true;
        assert!(!forced.is_lockstep());
    }

    #[test]
    fn scheduler_round_trip() {
        let mut sched = EdgeScheduler::new(
            SchedulerConfig::event(AdmissionPolicy::Fifo),
            Contention::new(1, 0.25),
        );
        for s in 0..3 {
            let ok = sched.submit(EdgeJob {
                session: s,
                p: 0,
                bytes: 100,
                capture_ms: 0.0,
                arrival_ms: s as f64,
                deadline_ms: 50.0,
                weight: 0.2,
                solo_ms: 6.0,
                seq: 0,
            });
            assert!(ok);
        }
        let out = sched.drain();
        assert_eq!(out.len(), 3);
        for (_, o) in &out {
            match o {
                Outcome::Served { batch_size, service_ms, .. } => {
                    assert_eq!(*batch_size, 3, "window should coalesce all three");
                    // 6 · (1 + 0.25·2) = 9 ms shared.
                    assert!((*service_ms - 9.0).abs() < 1e-9);
                }
                Outcome::Rejected => panic!("nothing should be rejected"),
            }
        }
        assert_eq!(sched.stats().dispatched, 3);
    }
}
