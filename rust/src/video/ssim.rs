//! Structural similarity (SSIM) — Wang, Bovik, Sheikh & Simoncelli 2004.
//!
//! Windowed implementation over 8×8 blocks with the standard stabilizing
//! constants (K1 = 0.01, K2 = 0.03, L = 255): per window,
//!
//! ```text
//! SSIM = (2 μx μy + C1)(2 σxy + C2) / ((μx² + μy² + C1)(σx² + σy² + C2))
//! ```
//!
//! and [`mean_ssim`] averages windows over the frame — the quantity the
//! paper thresholds for key-frame detection (Fig 6).

use super::stream::Frame;

const K1: f64 = 0.01;
const K2: f64 = 0.03;
const L: f64 = 255.0;
/// Window edge (8×8 blocks, standard for fast SSIM variants).
pub const WINDOW: usize = 8;

/// SSIM of one aligned window pair.
fn window_ssim(a: &Frame, b: &Frame, x0: usize, y0: usize, w: usize, h: usize) -> f64 {
    let n = (w * h) as f64;
    let (mut sa, mut sb) = (0.0, 0.0);
    for y in y0..y0 + h {
        for x in x0..x0 + w {
            sa += a.pixel(x, y) as f64;
            sb += b.pixel(x, y) as f64;
        }
    }
    let (ma, mb) = (sa / n, sb / n);
    let (mut va, mut vb, mut cov) = (0.0, 0.0, 0.0);
    for y in y0..y0 + h {
        for x in x0..x0 + w {
            let da = a.pixel(x, y) as f64 - ma;
            let db = b.pixel(x, y) as f64 - mb;
            va += da * da;
            vb += db * db;
            cov += da * db;
        }
    }
    va /= n;
    vb /= n;
    cov /= n;
    let c1 = (K1 * L) * (K1 * L);
    let c2 = (K2 * L) * (K2 * L);
    ((2.0 * ma * mb + c1) * (2.0 * cov + c2)) / ((ma * ma + mb * mb + c1) * (va + vb + c2))
}

/// Mean SSIM over all full 8×8 windows of two equally-sized frames.
/// Returns a value in [-1, 1]; 1 means structurally identical.
pub fn mean_ssim(a: &Frame, b: &Frame) -> f64 {
    assert_eq!(
        (a.width, a.height),
        (b.width, b.height),
        "SSIM needs equally sized frames"
    );
    assert!(a.width >= WINDOW && a.height >= WINDOW, "frame smaller than SSIM window");
    let mut total = 0.0;
    let mut count = 0;
    let mut y = 0;
    while y + WINDOW <= a.height {
        let mut x = 0;
        while x + WINDOW <= a.width {
            total += window_ssim(a, b, x, y, WINDOW, WINDOW);
            count += 1;
            x += WINDOW;
        }
        y += WINDOW;
    }
    total / count as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn frame_from(pixels: Vec<u8>, w: usize, h: usize) -> Frame {
        Frame { width: w, height: h, pixels, index: 0, is_event: false }
    }

    fn random_frame(seed: u64, w: usize, h: usize) -> Frame {
        let mut rng = Rng::new(seed);
        frame_from((0..w * h).map(|_| rng.below(256) as u8).collect(), w, h)
    }

    #[test]
    fn identical_frames_have_ssim_one() {
        let f = random_frame(1, 32, 32);
        assert!((mean_ssim(&f, &f) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn symmetric() {
        let a = random_frame(1, 32, 32);
        let b = random_frame(2, 32, 32);
        assert!((mean_ssim(&a, &b) - mean_ssim(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn bounded() {
        for s in 0..20 {
            let a = random_frame(s, 24, 24);
            let b = random_frame(s + 100, 24, 24);
            let v = mean_ssim(&a, &b);
            assert!((-1.0..=1.0).contains(&v), "ssim={v}");
        }
    }

    #[test]
    fn unrelated_noise_scores_low() {
        let a = random_frame(1, 64, 64);
        let b = random_frame(2, 64, 64);
        assert!(mean_ssim(&a, &b) < 0.2);
    }

    #[test]
    fn small_perturbation_scores_high() {
        let a = random_frame(3, 32, 32);
        let mut pixels = a.pixels.clone();
        for p in pixels.iter_mut() {
            *p = p.saturating_add(2);
        }
        let b = frame_from(pixels, 32, 32);
        assert!(mean_ssim(&a, &b) > 0.95);
    }

    #[test]
    fn constant_shift_detected_less_than_structure_change() {
        // Luminance-only shift vs structural scramble of the same frame.
        let a = random_frame(4, 32, 32);
        let mut shifted = a.pixels.clone();
        for p in shifted.iter_mut() {
            *p = p.saturating_add(30);
        }
        let shift = frame_from(shifted, 32, 32);
        let scrambled = random_frame(5, 32, 32);
        assert!(mean_ssim(&a, &shift) > mean_ssim(&a, &scrambled));
    }

    #[test]
    #[should_panic(expected = "equally sized")]
    fn size_mismatch_panics() {
        let a = random_frame(1, 32, 32);
        let b = random_frame(1, 16, 16);
        mean_ssim(&a, &b);
    }

    #[test]
    fn uniform_frames_max_similarity() {
        let a = frame_from(vec![100; 16 * 16], 16, 16);
        let b = frame_from(vec![100; 16 * 16], 16, 16);
        assert!((mean_ssim(&a, &b) - 1.0).abs() < 1e-12);
    }
}
