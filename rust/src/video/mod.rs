//! Video substrate: synthetic frame source, SSIM, key-frame detection.
//!
//! The paper's testbed captures 1280×720 camera frames and flags key
//! frames with SSIM against the previous frame (Fig 6) — key frames get
//! larger weights L_t in μLinUCB.  We have no camera, so [`stream`]
//! synthesizes a video: moving objects over a static background with
//! occasional scene cuts and object entrances — exactly the events SSIM
//! key-frame detection is meant to catch.  [`ssim`] is a full windowed
//! structural-similarity implementation (Wang et al. 2004), and
//! [`keyframe`] thresholds mean-SSIM to produce per-frame weights.

pub mod keyframe;
pub mod ssim;
pub mod stream;

pub use keyframe::{KeyframeDetector, Weights};
pub use ssim::mean_ssim;
pub use stream::{Frame, VideoStream};
