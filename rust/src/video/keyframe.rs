//! SSIM-threshold key-frame detection and frame weighting (paper §2.3).
//!
//! A frame is a **key frame** when its mean SSIM against the previous
//! frame falls below a threshold — it is "sufficiently different" (scene
//! change, object entrance).  Key frames receive weight `L_key`, others
//! `L_non_key`, with `0 < L_non_key < L_key < 1` (theory assumption (iv));
//! μLinUCB scales its confidence term by `√(1 − L_t)`, so key frames are
//! served exploitation-first.

use super::ssim::mean_ssim;
use super::stream::Frame;

/// Frame weights for key / non-key frames.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Weights {
    pub key: f64,
    pub non_key: f64,
}

impl Weights {
    pub fn new(key: f64, non_key: f64) -> Weights {
        assert!(
            0.0 < non_key && non_key < key && key < 1.0,
            "need 0 < L_non_key < L_key < 1, got non_key={non_key} key={key}"
        );
        Weights { key, non_key }
    }

    /// The paper's defaults (high differentiation).
    pub fn default_paper() -> Weights {
        Weights::new(0.8, 0.2)
    }
}

/// Stateful detector: compares each frame with its predecessor.
#[derive(Debug)]
pub struct KeyframeDetector {
    pub threshold: f64,
    pub weights: Weights,
    prev: Option<Frame>,
}

/// Per-frame detection outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrameClass {
    pub is_key: bool,
    /// SSIM against the previous frame (1.0 for the first frame).
    pub ssim: f64,
    /// The weight L_t handed to the learner.
    pub weight: f64,
}

impl KeyframeDetector {
    pub fn new(threshold: f64, weights: Weights) -> KeyframeDetector {
        assert!((0.0..=1.0).contains(&threshold));
        KeyframeDetector { threshold, weights, prev: None }
    }

    /// Classify the next frame of the stream.
    ///
    /// The first frame is always a key frame (it opens the scene).
    /// With `threshold = 1.0` every frame classifies as key (paper
    /// Fig 15(a): "when threshold is set to 1, all frames are key frames").
    pub fn classify(&mut self, frame: &Frame) -> FrameClass {
        let (is_key, ssim) = match &self.prev {
            None => (true, 1.0),
            Some(prev) => {
                let s = mean_ssim(prev, frame);
                (s < self.threshold, s)
            }
        };
        self.prev = Some(frame.clone());
        FrameClass {
            is_key,
            ssim,
            weight: if is_key { self.weights.key } else { self.weights.non_key },
        }
    }

    pub fn reset(&mut self) {
        self.prev = None;
    }

    /// Append the detector's mutable cursor (the retained previous frame)
    /// to a cold arena.  Threshold and weights are config.
    pub fn pack_cursor(&self, out: &mut Vec<u8>) {
        use crate::util::bytes::{put_bool, put_bytes, put_usize};
        match &self.prev {
            None => put_bool(out, false),
            Some(f) => {
                put_bool(out, true);
                put_usize(out, f.width);
                put_usize(out, f.height);
                put_usize(out, f.index);
                put_bool(out, f.is_event);
                put_bytes(out, &f.pixels);
            }
        }
    }

    /// Restore a cursor packed by [`KeyframeDetector::pack_cursor`].
    pub fn unpack_cursor(&mut self, r: &mut crate::util::bytes::Reader<'_>) {
        self.prev = if r.take_bool() {
            let width = r.take_usize();
            let height = r.take_usize();
            let index = r.take_usize();
            let is_event = r.take_bool();
            Some(Frame { width, height, pixels: r.take_bytes().to_vec(), index, is_event })
        } else {
            None
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::stream::VideoStream;

    fn detector(threshold: f64) -> KeyframeDetector {
        KeyframeDetector::new(threshold, Weights::default_paper())
    }

    #[test]
    fn first_frame_is_key() {
        let mut v = VideoStream::new(32, 32, 1);
        let mut d = detector(0.7);
        let c = d.classify(&v.next_frame());
        assert!(c.is_key);
        assert_eq!(c.weight, 0.8);
    }

    #[test]
    fn smooth_motion_is_not_key() {
        let mut v = VideoStream::new(48, 48, 2);
        v.scene_cut_prob = 0.0;
        v.entrance_prob = 0.0;
        let mut d = detector(0.7);
        d.classify(&v.next_frame());
        let keys = (0..50).filter(|_| d.classify(&v.next_frame()).is_key).count();
        assert!(keys <= 2, "smooth stream produced {keys} key frames");
    }

    #[test]
    fn scene_cuts_are_detected() {
        let mut v = VideoStream::new(48, 48, 3);
        v.scene_cut_prob = 0.0;
        v.entrance_prob = 0.0;
        let mut d = detector(0.8);
        d.classify(&v.next_frame());
        // Force a scene cut by constructing a very different stream frame.
        let mut v2 = VideoStream::new(48, 48, 999);
        let cut = v2.next_frame();
        let c = d.classify(&cut);
        assert!(c.is_key, "scene cut missed (ssim={})", c.ssim);
    }

    #[test]
    fn detector_tracks_ground_truth_events() {
        // On a stream with generated events, key-frame recall should be
        // decent: most scene cuts drop SSIM below a mid threshold.
        let mut v = VideoStream::new(64, 64, 4);
        v.scene_cut_prob = 0.05;
        v.entrance_prob = 0.0;
        let mut d = detector(0.85);
        d.classify(&v.next_frame());
        let (mut events, mut caught) = (0, 0);
        for _ in 0..300 {
            let f = v.next_frame();
            let c = d.classify(&f);
            if f.is_event {
                events += 1;
                if c.is_key {
                    caught += 1;
                }
            }
        }
        assert!(events > 5);
        assert!(
            caught as f64 >= 0.7 * events as f64,
            "recall {caught}/{events}"
        );
    }

    #[test]
    fn threshold_one_marks_everything_key() {
        let mut v = VideoStream::new(32, 32, 5);
        v.scene_cut_prob = 0.0;
        v.entrance_prob = 0.0;
        let mut d = detector(1.0);
        for _ in 0..20 {
            assert!(d.classify(&v.next_frame()).is_key);
        }
    }

    #[test]
    fn threshold_zero_marks_only_first_key() {
        let mut v = VideoStream::new(32, 32, 6);
        let mut d = detector(0.0);
        assert!(d.classify(&v.next_frame()).is_key);
        for _ in 0..20 {
            assert!(!d.classify(&v.next_frame()).is_key);
        }
    }

    #[test]
    #[should_panic(expected = "L_non_key < L_key")]
    fn weights_validated() {
        Weights::new(0.2, 0.8);
    }

    #[test]
    fn reset_restarts_detection() {
        let mut v = VideoStream::new(32, 32, 7);
        let mut d = detector(0.7);
        d.classify(&v.next_frame());
        d.reset();
        assert!(d.classify(&v.next_frame()).is_key);
    }
}
