//! Contextual feature construction: the paper's x_p (§2.2, Fig 5).
//!
//! x_p = [m_c, m_f, m_a, n_c, n_f, n_a, ψ_p]ᵀ — back-end MAC counts per
//! layer type, back-end layer counts per type, and the intermediate data
//! size crossing the link.  d = 7.  Raw counts span ~9 orders of
//! magnitude, so a [`FeatureScale`] normalizes them to O(1) before they
//! hit the ridge regression (conditioning of A_t); the scale is fixed
//! per-network so the linearity of the delay model is preserved.

use super::Network;

/// Context dimension d (paper: d = 7).
pub const CONTEXT_DIM: usize = 7;

/// A normalized context vector for one partition point.
pub type FeatureVector = [f64; CONTEXT_DIM];

/// Per-network normalization constants.
#[derive(Debug, Clone, Copy)]
pub struct FeatureScale {
    /// Divisor for MAC counts (per type).
    pub macs: f64,
    /// Divisor for layer counts.
    pub layers: f64,
    /// Divisor for ψ bytes.
    pub bytes: f64,
}

impl FeatureScale {
    /// Scale derived from the full network so every feature lands in ~[0, 1].
    pub fn for_network(net: &Network) -> FeatureScale {
        let full = net.backend_stats(0);
        let max_macs = full
            .macs_conv
            .max(full.macs_fc)
            .max(full.macs_act)
            .max(1) as f64;
        let max_layers = (full.n_conv.max(full.n_fc).max(full.n_act)).max(1) as f64;
        let max_bytes = (0..=net.num_partitions())
            .map(|p| net.intermediate_bytes(p))
            .max()
            .unwrap_or(1)
            .max(1) as f64;
        FeatureScale { macs: max_macs, layers: max_layers, bytes: max_bytes }
    }
}

/// Build the normalized x_p for every partition point of `net`.
///
/// `x_P` (pure on-device processing) is the **zero vector** — the paper's
/// Limitation #2: every θ predicts 0 edge-offloading delay for it, which
/// is what traps plain LinUCB and what μLinUCB's forced sampling escapes.
pub fn context_vectors(net: &Network, scale: &FeatureScale) -> Vec<FeatureVector> {
    (0..=net.num_partitions())
        .map(|p| context_vector(net, p, scale))
        .collect()
}

/// Build the normalized x_p for a single partition point.
pub fn context_vector(net: &Network, p: usize, scale: &FeatureScale) -> FeatureVector {
    let s = net.backend_stats(p);
    [
        s.macs_conv as f64 / scale.macs,
        s.macs_fc as f64 / scale.macs,
        s.macs_act as f64 / scale.macs,
        s.n_conv as f64 / scale.layers,
        s.n_fc as f64 / scale.layers,
        s.n_act as f64 / scale.layers,
        net.intermediate_bytes(p) as f64 / scale.bytes,
    ]
}

/// ℓ2 norm of a feature vector (the theory's C_x bound).
pub fn norm(x: &FeatureVector) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn mo_arm_is_zero_vector() {
        let net = zoo::vgg16();
        let scale = FeatureScale::for_network(&net);
        let xs = context_vectors(&net, &scale);
        let last = xs.last().unwrap();
        assert!(last.iter().all(|&v| v == 0.0), "x_P must be zero: {last:?}");
    }

    #[test]
    fn eo_arm_has_full_macs() {
        let net = zoo::vgg16();
        let scale = FeatureScale::for_network(&net);
        let x0 = context_vector(&net, 0, &scale);
        // Normalized conv MACs at p=0 equal max over types / itself = 1.
        assert!((x0[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn features_bounded_for_all_models() {
        for net in [zoo::vgg16(), zoo::yolo(), zoo::yolo_tiny(), zoo::resnet50(), zoo::partnet()] {
            let scale = FeatureScale::for_network(&net);
            for (p, x) in context_vectors(&net, &scale).iter().enumerate() {
                for (i, v) in x.iter().enumerate() {
                    assert!(
                        (0.0..=1.5).contains(v),
                        "{} p={p} feature[{i}]={v} out of range",
                        net.name
                    );
                }
                assert!(norm(x) <= 2.0, "{} p={p} |x|={}", net.name, norm(x));
            }
        }
    }

    #[test]
    fn mac_features_monotone_decreasing_in_p() {
        let net = zoo::vgg16();
        let scale = FeatureScale::for_network(&net);
        let xs = context_vectors(&net, &scale);
        for w in xs.windows(2) {
            assert!(w[0][0] >= w[1][0], "conv MACs must shrink with p");
            assert!(w[0][3] >= w[1][3], "conv layer count must shrink with p");
        }
    }

    #[test]
    fn psi_feature_non_monotone_for_vgg() {
        // conv1_1 inflates ψ over the raw input — the crux of the problem.
        let net = zoo::vgg16();
        let scale = FeatureScale::for_network(&net);
        let xs = context_vectors(&net, &scale);
        assert!(xs[1][6] > xs[0][6]);
        assert!(xs[net.num_partitions()][6] == 0.0);
    }

    #[test]
    fn distinct_partitions_have_distinct_contexts() {
        let net = zoo::vgg16();
        let scale = FeatureScale::for_network(&net);
        let xs = context_vectors(&net, &scale);
        for i in 0..xs.len() {
            for j in i + 1..xs.len() {
                assert_ne!(xs[i], xs[j], "p={i} vs p={j}");
            }
        }
    }
}
