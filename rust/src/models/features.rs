//! Contextual feature construction: the paper's x_p (§2.2, Fig 5),
//! widened with two queue-state dimensions (DESIGN.md §9).
//!
//! The paper's base context is
//! x_p = [m_c, m_f, m_a, n_c, n_f, n_a, ψ_p]ᵀ — back-end MAC counts per
//! layer type, back-end layer counts per type, and the intermediate data
//! size crossing the link (d = 7).  Raw counts span ~9 orders of
//! magnitude, so a [`FeatureScale`] normalizes them to O(1) before they
//! hit the ridge regression (conditioning of A_t); the scale is fixed
//! per-network so the linearity of the delay model is preserved.
//!
//! Dimensions [`QUEUE_MERGE_FEATURE`] and [`QUEUE_LOAD_FEATURE`] carry
//! the live edge-queue forecast ([`crate::edge::forecast`]) under
//! `--queue-signal full`: the batch-merge probability and the expected
//! service inflation of riding a cross-session batch.  The static
//! vectors built here leave them at **exactly 0.0** — the serving
//! engine writes them per frame when (and only when) the full queue
//! signal is on, so every legacy path sees zero queue dimensions.
//! Zeros in trailing dimensions leave the 7-dim ridge arithmetic
//! bit-identical (the βI prior block-diagonalizes and every product
//! against the extra coordinates is exactly 0.0), which is what keeps
//! the `--queue-signal off` transcripts pinned byte-for-byte.

use super::Network;

/// The paper's base context dimension (d = 7).
pub const BASE_CONTEXT_DIM: usize = 7;

/// Queue dimension: batch-merge probability
/// ([`crate::edge::EdgeEstimate::merge_probability`]).
pub const QUEUE_MERGE_FEATURE: usize = 7;

/// Queue dimension: expected batch service inflation,
/// `amortization − 1` ([`crate::edge::EdgeEstimate::amortization`]).
pub const QUEUE_LOAD_FEATURE: usize = 8;

/// Full context dimension: paper base + queue-state dimensions.
pub const CONTEXT_DIM: usize = BASE_CONTEXT_DIM + 2;

/// A normalized context vector for one partition point.
pub type FeatureVector = [f64; CONTEXT_DIM];

/// Per-network normalization constants.
#[derive(Debug, Clone, Copy)]
pub struct FeatureScale {
    /// Divisor for MAC counts (per type).
    pub macs: f64,
    /// Divisor for layer counts.
    pub layers: f64,
    /// Divisor for ψ bytes.
    pub bytes: f64,
}

impl FeatureScale {
    /// Scale derived from the full network so every feature lands in ~[0, 1].
    pub fn for_network(net: &Network) -> FeatureScale {
        let full = net.backend_stats(0);
        let max_macs = full
            .macs_conv
            .max(full.macs_fc)
            .max(full.macs_act)
            .max(1) as f64;
        let max_layers = (full.n_conv.max(full.n_fc).max(full.n_act)).max(1) as f64;
        let max_bytes = (0..=net.num_partitions())
            .map(|p| net.intermediate_bytes(p))
            .max()
            .unwrap_or(1)
            .max(1) as f64;
        FeatureScale { macs: max_macs, layers: max_layers, bytes: max_bytes }
    }
}

/// Build the normalized x_p for every partition point of `net`.
///
/// `x_P` (pure on-device processing) is the **zero vector** — the paper's
/// Limitation #2: every θ predicts 0 edge-offloading delay for it, which
/// is what traps plain LinUCB and what μLinUCB's forced sampling escapes.
pub fn context_vectors(net: &Network, scale: &FeatureScale) -> Vec<FeatureVector> {
    (0..=net.num_partitions())
        .map(|p| context_vector(net, p, scale))
        .collect()
}

/// Build the normalized x_p for a single partition point.  The queue
/// dimensions stay 0.0 — dynamic state the engine fills at select time.
pub fn context_vector(net: &Network, p: usize, scale: &FeatureScale) -> FeatureVector {
    let s = net.backend_stats(p);
    let mut x = [0.0; CONTEXT_DIM];
    x[0] = s.macs_conv as f64 / scale.macs;
    x[1] = s.macs_fc as f64 / scale.macs;
    x[2] = s.macs_act as f64 / scale.macs;
    x[3] = s.n_conv as f64 / scale.layers;
    x[4] = s.n_fc as f64 / scale.layers;
    x[5] = s.n_act as f64 / scale.layers;
    x[6] = net.intermediate_bytes(p) as f64 / scale.bytes;
    x
}

/// ℓ2 norm of a feature vector (the theory's C_x bound).
pub fn norm(x: &FeatureVector) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn static_vectors_leave_the_queue_dims_zero() {
        // The engine owns the queue dimensions; every statically built
        // vector must leave them at exactly 0.0 so legacy paths are
        // bit-identical to the 7-dim model.
        let net = zoo::vgg16();
        let scale = FeatureScale::for_network(&net);
        for (p, x) in context_vectors(&net, &scale).iter().enumerate() {
            assert_eq!(x[QUEUE_MERGE_FEATURE], 0.0, "p={p}");
            assert_eq!(x[QUEUE_LOAD_FEATURE], 0.0, "p={p}");
        }
        assert_eq!(CONTEXT_DIM, BASE_CONTEXT_DIM + 2);
    }

    #[test]
    fn mo_arm_is_zero_vector() {
        let net = zoo::vgg16();
        let scale = FeatureScale::for_network(&net);
        let xs = context_vectors(&net, &scale);
        let last = xs.last().unwrap();
        assert!(last.iter().all(|&v| v == 0.0), "x_P must be zero: {last:?}");
    }

    #[test]
    fn eo_arm_has_full_macs() {
        let net = zoo::vgg16();
        let scale = FeatureScale::for_network(&net);
        let x0 = context_vector(&net, 0, &scale);
        // Normalized conv MACs at p=0 equal max over types / itself = 1.
        assert!((x0[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn features_bounded_for_all_models() {
        for net in [zoo::vgg16(), zoo::yolo(), zoo::yolo_tiny(), zoo::resnet50(), zoo::partnet()] {
            let scale = FeatureScale::for_network(&net);
            for (p, x) in context_vectors(&net, &scale).iter().enumerate() {
                for (i, v) in x.iter().enumerate() {
                    assert!(
                        (0.0..=1.5).contains(v),
                        "{} p={p} feature[{i}]={v} out of range",
                        net.name
                    );
                }
                assert!(norm(x) <= 2.0, "{} p={p} |x|={}", net.name, norm(x));
            }
        }
    }

    #[test]
    fn mac_features_monotone_decreasing_in_p() {
        let net = zoo::vgg16();
        let scale = FeatureScale::for_network(&net);
        let xs = context_vectors(&net, &scale);
        for w in xs.windows(2) {
            assert!(w[0][0] >= w[1][0], "conv MACs must shrink with p");
            assert!(w[0][3] >= w[1][3], "conv layer count must shrink with p");
        }
    }

    #[test]
    fn psi_feature_non_monotone_for_vgg() {
        // conv1_1 inflates ψ over the raw input — the crux of the problem.
        let net = zoo::vgg16();
        let scale = FeatureScale::for_network(&net);
        let xs = context_vectors(&net, &scale);
        assert!(xs[1][6] > xs[0][6]);
        assert!(xs[net.num_partitions()][6] == 0.0);
    }

    #[test]
    fn distinct_partitions_have_distinct_contexts() {
        let net = zoo::vgg16();
        let scale = FeatureScale::for_network(&net);
        let xs = context_vectors(&net, &scale);
        for i in 0..xs.len() {
            for j in i + 1..xs.len() {
                assert_ne!(xs[i], xs[j], "p={i} vs p={j}");
            }
        }
    }
}
