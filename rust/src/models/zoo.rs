//! Benchmark networks: Vgg16, YoLo(v2), YoLo-tiny, ResNet50, PartNet.
//!
//! Structures follow the published architectures; partition points follow
//! the paper (after every layer group for chain DNNs; residual-block
//! granularity for ResNet50, which the paper notes has 16 blocks).
//! Input sizes match the paper §4.1: Vgg16/ResNet50 224×224×3,
//! YoLo/YoLo-tiny 416×416×3, PartNet 32×32×3 (the real served model).

use super::{Layer, Network, Shape, Stage};

fn conv(out_ch: usize, k: usize, stride: usize) -> Layer {
    Layer::Conv { out_ch, k, stride }
}

fn conv_act(name: &str, out_ch: usize, k: usize) -> Stage {
    Stage::new(name, vec![conv(out_ch, k, 1), Layer::Act])
}

fn pool2(name: &str) -> Stage {
    Stage::new(name, vec![Layer::Pool { k: 2, stride: 2 }])
}

fn fc_act(name: &str, out: usize) -> Stage {
    Stage::new(name, vec![Layer::Fc { out }, Layer::Act])
}

/// VGG-16 (Simonyan & Zisserman 2014): 13 conv + 5 pool + 3 fc = 21 stages.
pub fn vgg16() -> Network {
    Network {
        name: "vgg16".into(),
        input: Shape::Hwc(224, 224, 3),
        stages: vec![
            conv_act("conv1_1", 64, 3),
            conv_act("conv1_2", 64, 3),
            pool2("pool1"),
            conv_act("conv2_1", 128, 3),
            conv_act("conv2_2", 128, 3),
            pool2("pool2"),
            conv_act("conv3_1", 256, 3),
            conv_act("conv3_2", 256, 3),
            conv_act("conv3_3", 256, 3),
            pool2("pool3"),
            conv_act("conv4_1", 512, 3),
            conv_act("conv4_2", 512, 3),
            conv_act("conv4_3", 512, 3),
            pool2("pool4"),
            conv_act("conv5_1", 512, 3),
            conv_act("conv5_2", 512, 3),
            conv_act("conv5_3", 512, 3),
            pool2("pool5"),
            fc_act("fc1", 4096),
            fc_act("fc2", 4096),
            Stage::new("fc3", vec![Layer::Fc { out: 1000 }]),
        ],
    }
}

/// YOLOv2 (Redmon et al. 2016): Darknet-19 backbone + detection head.
pub fn yolo() -> Network {
    Network {
        name: "yolo".into(),
        input: Shape::Hwc(416, 416, 3),
        stages: vec![
            conv_act("conv1", 32, 3),
            pool2("pool1"),
            conv_act("conv2", 64, 3),
            pool2("pool2"),
            conv_act("conv3_1", 128, 3),
            conv_act("conv3_2", 64, 1),
            conv_act("conv3_3", 128, 3),
            pool2("pool3"),
            conv_act("conv4_1", 256, 3),
            conv_act("conv4_2", 128, 1),
            conv_act("conv4_3", 256, 3),
            pool2("pool4"),
            conv_act("conv5_1", 512, 3),
            conv_act("conv5_2", 256, 1),
            conv_act("conv5_3", 512, 3),
            conv_act("conv5_4", 256, 1),
            conv_act("conv5_5", 512, 3),
            pool2("pool5"),
            conv_act("conv6_1", 1024, 3),
            conv_act("conv6_2", 512, 1),
            conv_act("conv6_3", 1024, 3),
            conv_act("conv6_4", 512, 1),
            conv_act("conv6_5", 1024, 3),
            conv_act("conv7_1", 1024, 3),
            conv_act("conv7_2", 1024, 3),
            Stage::new("conv8", vec![conv(425, 1, 1)]),
        ],
    }
}

/// Tiny-YOLOv2: the compressed model used in Fig 16 (paper reports 7.76×
/// less runtime than the full YoLo).
pub fn yolo_tiny() -> Network {
    Network {
        name: "yolo_tiny".into(),
        input: Shape::Hwc(416, 416, 3),
        stages: vec![
            conv_act("conv1", 16, 3),
            pool2("pool1"),
            conv_act("conv2", 32, 3),
            pool2("pool2"),
            conv_act("conv3", 64, 3),
            pool2("pool3"),
            conv_act("conv4", 128, 3),
            pool2("pool4"),
            conv_act("conv5", 256, 3),
            pool2("pool5"),
            conv_act("conv6", 512, 3),
            Stage::new("pool6", vec![Layer::Pool { k: 2, stride: 1 }]),
            conv_act("conv7", 1024, 3),
            conv_act("conv8", 1024, 3),
            Stage::new("conv9", vec![conv(425, 1, 1)]),
        ],
    }
}

/// One ResNet bottleneck block: 1×1 reduce → 3×3 → 1×1 expand (+ add + act).
/// `stride` applies to the 3×3 (and the projection shortcut on the first
/// block of a group).  Costed as a single stage: the paper partitions
/// ResNet50 at residual-block granularity.
fn bottleneck(name: &str, mid: usize, out: usize, stride: usize) -> Stage {
    Stage::new(
        name,
        vec![
            conv(mid, 1, 1),
            Layer::Act,
            conv(mid, 3, stride),
            Layer::Act,
            conv(out, 1, 1),
            Layer::Add,
            Layer::Act,
        ],
    )
}

/// ResNet-50 (He et al. 2016): stem + 16 bottleneck blocks + head.
pub fn resnet50() -> Network {
    let mut stages = vec![
        Stage::new(
            "stem",
            vec![conv(64, 7, 2), Layer::Act, Layer::Pool { k: 2, stride: 2 }],
        ),
    ];
    let groups: [(usize, usize, usize, usize); 4] = [
        // (num_blocks, mid_ch, out_ch, first_stride)
        (3, 64, 256, 1),
        (4, 128, 512, 2),
        (6, 256, 1024, 2),
        (3, 512, 2048, 2),
    ];
    for (g, &(blocks, mid, out, first_stride)) in groups.iter().enumerate() {
        for b in 0..blocks {
            let stride = if b == 0 { first_stride } else { 1 };
            stages.push(bottleneck(&format!("res{}_{}", g + 2, b + 1), mid, out, stride));
        }
    }
    stages.push(Stage::new(
        "head",
        vec![Layer::GlobalPool, Layer::Fc { out: 1000 }],
    ));
    Network { name: "resnet50".into(), input: Shape::Hwc(224, 224, 3), stages }
}

/// PartNet: the small CNN actually served end-to-end through PJRT.
/// MUST mirror `python/compile/model.py::STAGES` — the integration test
/// cross-checks these stats against `artifacts/manifest.json`.
pub fn partnet() -> Network {
    Network {
        name: "partnet".into(),
        input: Shape::Hwc(32, 32, 3),
        stages: vec![
            conv_act("conv1", 16, 3),
            pool2("pool1"),
            conv_act("conv2", 32, 3),
            pool2("pool2"),
            conv_act("conv3", 64, 3),
            pool2("pool3"),
            fc_act("fc1", 256),
            fc_act("fc2", 64),
            Stage::new("fc3", vec![Layer::Fc { out: 16 }]),
        ],
    }
}

/// Canonical names accepted by [`by_name`] (CLI help / validation).
pub const MODEL_NAMES: &[&str] = &["vgg16", "yolo", "yolo_tiny", "resnet50", "partnet"];

/// Look a network up by name (CLI / config entry point).
pub fn by_name(name: &str) -> Option<Network> {
    match name {
        "vgg16" => Some(vgg16()),
        "yolo" => Some(yolo()),
        "yolo_tiny" | "yolo-tiny" => Some(yolo_tiny()),
        "resnet50" => Some(resnet50()),
        "partnet" => Some(partnet()),
        _ => None,
    }
}

/// All paper-scale networks (Table 1 / Fig 11 iterate over these).
pub fn paper_models() -> Vec<Network> {
    vec![vgg16(), yolo(), resnet50()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Shape;

    #[test]
    fn vgg16_structure() {
        let n = vgg16();
        assert_eq!(n.num_partitions(), 21);
        assert_eq!(n.output_shape(), Shape::Flat(1000));
        // Published figure: ~15.5 GMACs for 224x224 VGG-16 convs+fcs.
        let s = n.backend_stats(0);
        let gmacs = (s.macs_conv + s.macs_fc) as f64 / 1e9;
        assert!((15.0..16.0).contains(&gmacs), "vgg16 gmacs={gmacs}");
        assert_eq!(s.n_conv, 13);
        assert_eq!(s.n_fc, 3);
    }

    #[test]
    fn vgg16_fc1_is_the_bottleneck_crossing() {
        // After pool5 the tensor is 7x7x512 = 100k elems; fc1 output is 4096.
        let n = vgg16();
        let pool5 = n.stage_names().iter().position(|s| *s == "pool5").unwrap() + 1;
        assert_eq!(n.intermediate_shape(pool5), Shape::Hwc(7, 7, 512));
        let fc1 = pool5 + 1;
        assert_eq!(n.intermediate_shape(fc1), Shape::Flat(4096));
        // ψ drops by ~6x at fc1 (and ~4x pool5 vs raw input) — why the
        // paper's Fig 1 optimum sits at the conv/fc boundary.
        assert!(n.intermediate_bytes(pool5) > 5 * n.intermediate_bytes(fc1));
    }

    #[test]
    fn yolo_structure() {
        let n = yolo();
        assert_eq!(n.output_shape(), Shape::Hwc(13, 13, 425));
        let s = n.backend_stats(0);
        // Our chain keeps YOLOv2's 21 convolution stages (the reorg
        // passthrough is omitted; it has no partition-relevant cost).
        assert_eq!(s.n_conv, 21);
        // YOLOv2 is ~29.5 GFLOPs at 416x416 ≈ ~14.7 GMACs; ours is ~12.7
        // (reorg/concat path omitted).
        let gmacs = s.macs_conv as f64 / 1e9;
        assert!((10.0..18.0).contains(&gmacs), "yolo gmacs={gmacs}");
    }

    #[test]
    fn yolo_tiny_much_smaller_than_yolo() {
        let t = yolo_tiny().backend_stats(0).total_macs() as f64;
        let y = yolo().backend_stats(0).total_macs() as f64;
        // Paper: 7.76x runtime reduction; MACs ratio should be of that order.
        assert!(y / t > 3.0, "ratio={}", y / t);
    }

    #[test]
    fn resnet50_structure() {
        let n = resnet50();
        // stem + 16 blocks + head = 18 stages.
        assert_eq!(n.num_partitions(), 18);
        assert_eq!(n.output_shape(), Shape::Flat(1000));
        let s = n.backend_stats(0);
        // ~3.8-4.1 GMACs for ResNet50 (ours omits the projection convs).
        let gmacs = s.macs_conv as f64 / 1e9;
        assert!((3.0..4.5).contains(&gmacs), "resnet50 gmacs={gmacs}");
    }

    #[test]
    fn resnet50_block_shapes() {
        let n = resnet50();
        assert_eq!(n.intermediate_shape(1), Shape::Hwc(56, 56, 64)); // after stem
        assert_eq!(n.intermediate_shape(4), Shape::Hwc(56, 56, 256)); // after res2
        assert_eq!(n.intermediate_shape(8), Shape::Hwc(28, 28, 512)); // after res3
        assert_eq!(n.intermediate_shape(14), Shape::Hwc(14, 14, 1024)); // after res4
        assert_eq!(n.intermediate_shape(17), Shape::Hwc(7, 7, 2048)); // after res5
    }

    #[test]
    fn partnet_matches_python_model() {
        // Mirrors python/compile/model.py: shapes at every partition point.
        let n = partnet();
        assert_eq!(n.num_partitions(), 9);
        let want = [
            Shape::Hwc(32, 32, 3),
            Shape::Hwc(32, 32, 16),
            Shape::Hwc(16, 16, 16),
            Shape::Hwc(16, 16, 32),
            Shape::Hwc(8, 8, 32),
            Shape::Hwc(8, 8, 64),
            Shape::Hwc(4, 4, 64),
            Shape::Flat(256),
            Shape::Flat(64),
            Shape::Flat(16),
        ];
        for (p, w) in want.iter().enumerate() {
            assert_eq!(n.intermediate_shape(p), *w, "p={p}");
        }
        // Feature cross-check against python's backend_features(0).
        let s = n.backend_stats(0);
        assert_eq!(s.macs_conv, 2_801_664);
        assert_eq!(s.macs_fc, 279_552);
        assert_eq!(s.n_conv, 3);
        assert_eq!(s.n_fc, 3);
    }

    #[test]
    fn by_name_roundtrip() {
        for name in MODEL_NAMES {
            assert_eq!(by_name(name).unwrap().name, *name);
        }
        assert!(by_name("alexnet").is_none());
    }

    #[test]
    fn all_models_have_nonmonotone_psi() {
        // The partition problem is only interesting if ψ_p is non-monotone
        // or at least non-trivially shaped; early convs inflate channels.
        for n in [vgg16(), yolo(), yolo_tiny(), partnet()] {
            let sizes: Vec<usize> =
                (0..=n.num_partitions()).map(|p| n.intermediate_bytes(p)).collect();
            assert!(sizes[1] > sizes[0], "{}: conv1 must inflate", n.name);
            assert!(*sizes.last().unwrap() < sizes[0], "{}: output must shrink", n.name);
        }
    }
}
