//! DNN layer-graph IR: shape inference, MAC counting, partition points.
//!
//! The paper's contextual features are functions of the network *structure*
//! (multiply-accumulate counts per layer type, layer counts per type,
//! intermediate tensor size).  This module gives every benchmark network a
//! common IR from which those quantities are derived:
//!
//! * a [`Network`] is a chain of [`Stage`]s; a **partition point** sits
//!   after each stage (`p = 0` ⇒ pure edge offloading, `p = P` ⇒ pure
//!   on-device processing), matching the paper's marking scheme — for
//!   chain DNNs each layer group is a stage, for ResNet50 each residual
//!   block is a stage (the paper's residual-block method);
//! * a [`Stage`] is a list of [`Layer`]s that must stay together;
//! * per-layer MACs follow the conventions in the paper §2.2: convolution
//!   and fully-connected MACs from the arithmetic, activation "MACs" are
//!   one unit per output element (elementwise, memory-bound).

pub mod features;
pub mod zoo;

pub use features::{
    FeatureScale, FeatureVector, BASE_CONTEXT_DIM, CONTEXT_DIM, QUEUE_LOAD_FEATURE,
    QUEUE_MERGE_FEATURE,
};

/// Tensor shape flowing between layers (f32 throughout, NHWC for images).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    /// Spatial feature map: height, width, channels (batch implicit).
    Hwc(usize, usize, usize),
    /// Flattened vector of the given width.
    Flat(usize),
}

impl Shape {
    pub fn elems(&self) -> usize {
        match *self {
            Shape::Hwc(h, w, c) => h * w * c,
            Shape::Flat(n) => n,
        }
    }

    /// Bytes on the wire for batch size 1 (f32).
    pub fn bytes(&self) -> usize {
        self.elems() * 4
    }
}

/// One DNN layer. MAC/shape semantics in [`Layer::out_shape`] / [`Layer::macs`].
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    /// 2-D convolution, square kernel, SAME-style padding unless `valid`.
    Conv { out_ch: usize, k: usize, stride: usize },
    /// Fully connected (flattens its input implicitly).
    Fc { out: usize },
    /// Elementwise activation (ReLU / leaky — identical cost model).
    Act,
    /// Max/avg pool, square window.
    Pool { k: usize, stride: usize },
    /// Global average pool: HWC -> Flat(C).
    GlobalPool,
    /// Residual add (elementwise, costed like an activation layer).
    Add,
}

/// The three layer-type buckets the paper builds features from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerType {
    Conv,
    Fc,
    Act,
}

impl Layer {
    /// Output shape given the input shape.
    pub fn out_shape(&self, input: Shape) -> Shape {
        match (self, input) {
            (Layer::Conv { out_ch, stride, .. }, Shape::Hwc(h, w, _)) => {
                Shape::Hwc(h.div_ceil(*stride), w.div_ceil(*stride), *out_ch)
            }
            (Layer::Fc { out }, _) => Shape::Flat(*out),
            (Layer::Act, s) | (Layer::Add, s) => s,
            (Layer::Pool { stride, .. }, Shape::Hwc(h, w, c)) => {
                Shape::Hwc(h / stride, w / stride, c)
            }
            (Layer::GlobalPool, Shape::Hwc(_, _, c)) => Shape::Flat(c),
            (l, s) => panic!("layer {l:?} cannot take input shape {s:?}"),
        }
    }

    /// Multiply-accumulate count for batch 1 with the given input shape.
    pub fn macs(&self, input: Shape) -> u64 {
        let out = self.out_shape(input);
        match (self, input) {
            (Layer::Conv { k, .. }, Shape::Hwc(_, _, cin)) => {
                (out.elems() * k * k * cin) as u64
            }
            (Layer::Fc { out }, i) => (i.elems() * out) as u64,
            (Layer::Act, _) | (Layer::Add, _) => out.elems() as u64,
            (Layer::Pool { k, .. }, _) => (out.elems() * k * k) as u64,
            (Layer::GlobalPool, i) => i.elems() as u64,
            (l, s) => panic!("layer {l:?} cannot take input shape {s:?}"),
        }
    }

    /// Which feature bucket this layer contributes to.
    pub fn layer_type(&self) -> LayerType {
        match self {
            Layer::Conv { .. } => LayerType::Conv,
            Layer::Fc { .. } => LayerType::Fc,
            Layer::Act | Layer::Pool { .. } | Layer::GlobalPool | Layer::Add => LayerType::Act,
        }
    }
}

/// A named group of layers between two adjacent partition points.
#[derive(Debug, Clone)]
pub struct Stage {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Stage {
    pub fn new(name: &str, layers: Vec<Layer>) -> Stage {
        Stage { name: name.to_string(), layers }
    }

    pub fn out_shape(&self, mut input: Shape) -> Shape {
        for l in &self.layers {
            input = l.out_shape(input);
        }
        input
    }
}

/// Aggregated structural statistics of a span of stages.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SpanStats {
    pub macs_conv: u64,
    pub macs_fc: u64,
    pub macs_act: u64,
    pub n_conv: u64,
    pub n_fc: u64,
    pub n_act: u64,
    /// Number of fusable (conv|fc → act) adjacent pairs — the inter-layer
    /// optimization the simulator's ground truth discounts and layer-wise
    /// profiling misses (DESIGN.md §4).
    pub fused_pairs: u64,
    /// MACs of activation layers that fuse into their producer (their
    /// elementwise pass runs as a register epilogue: no extra launch, no
    /// memory round-trip).
    pub macs_fused_act: u64,
}

impl SpanStats {
    pub fn total_macs(&self) -> u64 {
        self.macs_conv + self.macs_fc + self.macs_act
    }
}

/// A partitionable DNN: input shape plus the stage chain.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub input: Shape,
    pub stages: Vec<Stage>,
}

impl Network {
    /// Number of partition points P (valid p ∈ 0..=P).
    pub fn num_partitions(&self) -> usize {
        self.stages.len()
    }

    /// Shape of ψ_p — the tensor crossing the link when partitioned at `p`.
    pub fn intermediate_shape(&self, p: usize) -> Shape {
        assert!(p <= self.stages.len(), "partition {p} out of range");
        let mut s = self.input;
        for stage in &self.stages[..p] {
            s = stage.out_shape(s);
        }
        s
    }

    /// Bytes of ψ_p on the wire (0 for p = P: nothing is transmitted).
    pub fn intermediate_bytes(&self, p: usize) -> usize {
        if p == self.num_partitions() {
            0
        } else {
            self.intermediate_shape(p).bytes()
        }
    }

    /// Structural stats over stages `[from, to)`.
    pub fn span_stats(&self, from: usize, to: usize) -> SpanStats {
        assert!(from <= to && to <= self.stages.len());
        let mut s = SpanStats::default();
        let mut shape = self.intermediate_shape(from);
        let mut prev_was_compute = false;
        for stage in &self.stages[from..to] {
            for layer in &stage.layers {
                let macs = layer.macs(shape);
                match layer.layer_type() {
                    LayerType::Conv => {
                        s.macs_conv += macs;
                        s.n_conv += 1;
                    }
                    LayerType::Fc => {
                        s.macs_fc += macs;
                        s.n_fc += 1;
                    }
                    LayerType::Act => {
                        s.macs_act += macs;
                        s.n_act += 1;
                    }
                }
                // conv/fc immediately followed by an activation fuses (cuDNN-style).
                let is_compute = !matches!(layer.layer_type(), LayerType::Act);
                if prev_was_compute && matches!(layer, Layer::Act) {
                    s.fused_pairs += 1;
                    s.macs_fused_act += macs;
                }
                prev_was_compute = is_compute;
                shape = layer.out_shape(shape);
            }
        }
        s
    }

    /// Stats of the back-end partition DNN_p^back (stages p..P).
    pub fn backend_stats(&self, p: usize) -> SpanStats {
        self.span_stats(p, self.stages.len())
    }

    /// Stats of the front-end partition DNN_p^front (stages 0..p).
    pub fn frontend_stats(&self, p: usize) -> SpanStats {
        self.span_stats(0, p)
    }

    /// Output shape of the whole network.
    pub fn output_shape(&self) -> Shape {
        self.intermediate_shape(self.num_partitions())
    }

    /// Stage names, aligned with partition point p = index + 1.
    pub fn stage_names(&self) -> Vec<&str> {
        self.stages.iter().map(|s| s.name.as_str()).collect()
    }

    /// Human label for partition point `p` (for traces and reports).
    pub fn partition_label(&self, p: usize) -> String {
        if p == 0 {
            "input(EO)".to_string()
        } else if p == self.num_partitions() {
            format!("{}(MO)", self.stages[p - 1].name)
        } else {
            self.stages[p - 1].name.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Network {
        Network {
            name: "toy".into(),
            input: Shape::Hwc(8, 8, 3),
            stages: vec![
                Stage::new("conv1", vec![Layer::Conv { out_ch: 4, k: 3, stride: 1 }, Layer::Act]),
                Stage::new("pool1", vec![Layer::Pool { k: 2, stride: 2 }]),
                Stage::new("fc1", vec![Layer::Fc { out: 10 }, Layer::Act]),
            ],
        }
    }

    #[test]
    fn shape_inference() {
        let n = toy();
        assert_eq!(n.intermediate_shape(0), Shape::Hwc(8, 8, 3));
        assert_eq!(n.intermediate_shape(1), Shape::Hwc(8, 8, 4));
        assert_eq!(n.intermediate_shape(2), Shape::Hwc(4, 4, 4));
        assert_eq!(n.intermediate_shape(3), Shape::Flat(10));
        assert_eq!(n.output_shape(), Shape::Flat(10));
    }

    #[test]
    fn conv_macs() {
        // 8x8x4 outputs, 3x3x3 window each.
        let l = Layer::Conv { out_ch: 4, k: 3, stride: 1 };
        assert_eq!(l.macs(Shape::Hwc(8, 8, 3)), (8 * 8 * 4 * 3 * 3 * 3) as u64);
    }

    #[test]
    fn fc_macs_flatten_implicitly() {
        let l = Layer::Fc { out: 10 };
        assert_eq!(l.macs(Shape::Hwc(4, 4, 4)), (4 * 4 * 4 * 10) as u64);
        assert_eq!(l.out_shape(Shape::Hwc(4, 4, 4)), Shape::Flat(10));
    }

    #[test]
    fn strided_conv_halves_spatial() {
        let l = Layer::Conv { out_ch: 64, k: 7, stride: 2 };
        assert_eq!(l.out_shape(Shape::Hwc(224, 224, 3)), Shape::Hwc(112, 112, 64));
    }

    #[test]
    fn macs_conserve_across_partition() {
        let n = toy();
        let total = n.backend_stats(0);
        for p in 0..=n.num_partitions() {
            let f = n.frontend_stats(p);
            let b = n.backend_stats(p);
            assert_eq!(f.total_macs() + b.total_macs(), total.total_macs(), "p={p}");
            assert_eq!(f.n_conv + b.n_conv, total.n_conv);
        }
    }

    #[test]
    fn backend_stats_at_p_max_is_zero() {
        let n = toy();
        let b = n.backend_stats(n.num_partitions());
        assert_eq!(b, SpanStats::default());
        assert_eq!(n.intermediate_bytes(n.num_partitions()), 0);
    }

    #[test]
    fn fused_pairs_counted() {
        let n = toy();
        // conv1+act and fc1+act fuse; pool does not.
        assert_eq!(n.backend_stats(0).fused_pairs, 2);
        assert_eq!(n.backend_stats(1).fused_pairs, 1);
    }

    #[test]
    fn partition_labels() {
        let n = toy();
        assert_eq!(n.partition_label(0), "input(EO)");
        assert_eq!(n.partition_label(1), "conv1");
        assert_eq!(n.partition_label(3), "fc1(MO)");
    }

    #[test]
    fn global_pool_flattens() {
        let l = Layer::GlobalPool;
        assert_eq!(l.out_shape(Shape::Hwc(7, 7, 2048)), Shape::Flat(2048));
        assert_eq!(l.macs(Shape::Hwc(7, 7, 2048)), (7 * 7 * 2048) as u64);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn intermediate_shape_bounds() {
        toy().intermediate_shape(99);
    }
}
