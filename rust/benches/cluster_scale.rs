//! Cluster-scale sweep: replicas × sessions (§Scale, the replica tier's
//! acceptance exhibit).
//!
//! For each cell the bench serves the same deterministic fleet —
//! heterogeneous per-session uplinks, one μLinUCB learner per session —
//! through the replica cluster at 1/2/4 replicas and reports frames/sec
//! plus the fleet mean delay.  Replication is a *simulated-capacity*
//! axis, not a wall-clock one: more replicas means more edge executors
//! sharing the fleet (lower contention, lower delay), while the serving
//! work per frame stays the same, so frames/sec mainly tracks router +
//! per-replica bookkeeping overhead.  The cluster is bit-identical at
//! every worker count (pinned in `rust/tests/cluster.rs`), so none of
//! this sweep is behaviour drift.
//!
//! Results land in `bench_results/cluster_scale.json`; CI runs the
//! sweep in smoke mode (`BENCH_SAMPLES=3`) and uploads the artifact
//! alongside the other bench JSONs.
//!
//! The second sweep (DESIGN.md §15) runs the SAME config-described fleet
//! through the in-process cluster and through `--distribute process`
//! (one child per replica over the framed protocol) and reports the
//! honest wall-clock ratio into `bench_results/distributed_scale.json`.
//! Honesty has two legs: `host_cores` is recorded next to every speedup
//! (a 1-core box cannot show >1× and the JSON says so), and both modes'
//! per-session transcripts are checksummed and asserted identical — a
//! speedup obtained by drifting from the in-process decisions aborts the
//! bench instead of reporting.

use ans::bandit;
use ans::config::Config;
use ans::coordinator::cluster::{
    cluster_from_config, Cluster, ClusterConfig, Placement, ReplicaSpec,
};
use ans::coordinator::engine::EngineConfig;
use ans::coordinator::{FrameSource, ProcessCluster};
use ans::models::zoo;
use ans::simulator::{scenario, Contention, Workload, DEVICE_MAXN, EDGE_GPU};
use ans::util::bench::Bench;
use ans::util::json::{obj, Json};
use std::time::Instant;

const REPLICAS: &[usize] = &[1, 2, 4];
const SESSIONS: &[usize] = &[64, 256];
/// Total session-frames per run, held roughly constant across fleet
/// sizes so every cell does comparable work.
const FRAME_BUDGET: usize = 20_000;

fn build_cluster(sessions: usize, replicas: usize, placement: Placement) -> Cluster {
    let net = zoo::partnet();
    let rounds = (FRAME_BUDGET / sessions).max(20);
    let mut cl = Cluster::new(
        ClusterConfig::new(
            EngineConfig {
                contention: Contention::new(2, 0.25),
                ingress_mbps: Some(400.0),
                ..Default::default()
            },
            placement,
            50,
        ),
        ReplicaSpec::uniform(replicas, EDGE_GPU, Workload::constant(1.0)),
    );
    for env in scenario::fleet(net.clone(), sessions, 12.0, 7) {
        let policy =
            bandit::by_name("mu-linucb", &net, &DEVICE_MAXN, &EDGE_GPU, rounds, None, None)
                .expect("known policy");
        cl.add_session(policy, env, FrameSource::uniform());
    }
    cl
}

/// Serve the scenario once; returns (frames/sec, fleet mean delay ms).
fn serve_once(sessions: usize, replicas: usize, placement: Placement) -> (f64, f64) {
    let rounds = (FRAME_BUDGET / sessions).max(20);
    let mut cl = build_cluster(sessions, replicas, placement);
    let start = Instant::now();
    cl.run(rounds);
    let secs = start.elapsed().as_secs_f64();
    let fs = cl.fleet_summary();
    ((sessions * rounds) as f64 / secs.max(1e-9), fs.aggregate.mean_delay_ms)
}

/// The config-described twin of the sweep fleet, for the distributed
/// comparison (process workers bootstrap from the embedded config, so
/// this sweep must go through [`cluster_from_config`], not the manual
/// builder above).
fn config_for(sessions: usize, replicas: usize) -> Config {
    let mut cfg = Config::default();
    cfg.sessions = sessions;
    cfg.replicas = replicas;
    cfg.frames = (FRAME_BUDGET / sessions).max(20);
    cfg.rate_mbps = 12.0;
    cfg.seed = 7;
    cfg.placement = "least-loaded".into();
    cfg.distribute = "process".into();
    cfg.worker_exe = env!("CARGO_BIN_EXE_ans").into();
    cfg
}

/// FNV-1a over every session's packed per-frame records, in canonical
/// session order — the bit-identity witness both modes must share.
fn transcript_checksum(cl: &Cluster) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut buf = Vec::new();
    for s in cl.sessions() {
        buf.clear();
        s.metrics.pack(&mut buf);
        for &byte in &buf {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// One in-process serve of the config fleet: (frames/sec, checksum).
fn serve_inproc(cfg: &Config) -> (f64, u64) {
    let mut cl = cluster_from_config(cfg);
    let start = Instant::now();
    cl.run(cfg.frames);
    let secs = start.elapsed().as_secs_f64();
    ((cfg.sessions * cfg.frames) as f64 / secs.max(1e-9), transcript_checksum(&cl))
}

/// One process-per-replica serve: (frames/sec over the framed rounds,
/// child bootstrap+merge overhead ms, checksum).  The serving clock
/// covers only the round protocol; spawn/bootstrap/merge are reported
/// separately so the steady-state ratio is not diluted by startup.
fn serve_process(cfg: &Config) -> (f64, f64, u64) {
    let setup = Instant::now();
    let state = cluster_from_config(cfg).snapshot_state();
    let mut pc = ProcessCluster::launch(cfg, &state).expect("launching replica workers");
    let mut overhead = setup.elapsed().as_secs_f64();
    let start = Instant::now();
    pc.run(cfg.frames).expect("distributed run");
    let secs = start.elapsed().as_secs_f64();
    let merge = Instant::now();
    let merged = pc.finish().expect("merging replica states");
    overhead += merge.elapsed().as_secs_f64();
    (
        (cfg.sessions * cfg.frames) as f64 / secs.max(1e-9),
        1e3 * overhead,
        transcript_checksum(&merged),
    )
}

fn distributed_sweep(b: &Bench, samples: usize) {
    let host_cores =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut rows: Vec<Json> = Vec::new();
    for &sessions in SESSIONS {
        let name = format!("distributed_scale/s{sessions}");
        if !b.enabled(&name) {
            continue;
        }
        for &replicas in REPLICAS {
            let cfg = config_for(sessions, replicas);
            let mut best_in = 0.0_f64;
            let mut best_proc = 0.0_f64;
            let mut overhead_ms = f64::INFINITY;
            let mut checksum = 0u64;
            for _ in 0..samples {
                let (fps_in, sum_in) = serve_inproc(&cfg);
                let (fps_proc, over, sum_proc) = serve_process(&cfg);
                assert_eq!(
                    sum_in, sum_proc,
                    "s{sessions} r{replicas}: process transcripts drifted from in-process"
                );
                best_in = best_in.max(fps_in);
                best_proc = best_proc.max(fps_proc);
                overhead_ms = overhead_ms.min(over);
                checksum = sum_in;
            }
            let speedup = best_proc / best_in.max(1e-9);
            println!(
                "{name:<32} replicas {replicas}  in-proc {best_in:>10.0} f/s  process \
                 {best_proc:>10.0} f/s  (x{speedup:.2}, {host_cores} core(s), setup \
                 {overhead_ms:.0} ms)"
            );
            rows.push(obj(vec![
                ("sessions", Json::from(sessions)),
                ("replicas", Json::from(replicas)),
                ("rounds", Json::from(cfg.frames)),
                ("inproc_frames_per_sec", Json::from(best_in)),
                ("process_frames_per_sec", Json::from(best_proc)),
                ("speedup", Json::from(speedup)),
                ("setup_overhead_ms", Json::from(overhead_ms)),
                ("transcript_checksum", Json::from(format!("{checksum:016x}"))),
            ]));
        }
    }
    if rows.is_empty() {
        return;
    }
    let doc = obj(vec![
        ("bench", Json::from("distributed_scale")),
        ("samples", Json::from(samples)),
        ("frame_budget", Json::from(FRAME_BUDGET)),
        ("host_cores", Json::from(host_cores)),
        ("results", Json::Arr(rows)),
    ]);
    std::fs::create_dir_all("bench_results").expect("creating bench_results/");
    std::fs::write("bench_results/distributed_scale.json", doc.to_string())
        .expect("writing bench_results/distributed_scale.json");
    println!("distributed sweep JSON -> bench_results/distributed_scale.json");
}

fn main() {
    let b = Bench::from_env();
    let samples = b.samples.max(1);
    println!("cluster_scale: {} sample(s) per cell", samples);

    let mut rows: Vec<Json> = Vec::new();
    for &sessions in SESSIONS {
        let name = format!("cluster_scale/s{sessions}");
        if !b.enabled(&name) {
            continue;
        }
        let mut base_fps = 0.0;
        for &replicas in REPLICAS {
            // Best-of-samples frames/sec (least-noisy machine estimate);
            // the mean delay is deterministic across samples.
            let mut best = 0.0_f64;
            let mut mean_delay = f64::NAN;
            for _ in 0..samples {
                let (fps, delay) = serve_once(sessions, replicas, Placement::LeastLoaded);
                best = best.max(fps);
                mean_delay = delay;
            }
            if replicas == 1 {
                base_fps = best;
            }
            let relative = if base_fps > 0.0 { best / base_fps } else { 1.0 };
            println!(
                "{name:<32} replicas {replicas}  {best:>12.0} frames/s  (x{relative:.2} vs 1 \
                 replica)  fleet mean {mean_delay:>8.1} ms"
            );
            rows.push(obj(vec![
                ("sessions", Json::from(sessions)),
                ("replicas", Json::from(replicas)),
                ("frames_per_sec", Json::from(best)),
                ("throughput_vs_1_replica", Json::from(relative)),
                ("mean_delay_ms", Json::from(mean_delay)),
            ]));
        }
    }

    let doc = obj(vec![
        ("bench", Json::from("cluster_scale")),
        ("samples", Json::from(samples)),
        ("frame_budget", Json::from(FRAME_BUDGET)),
        ("placement", Json::from("least-loaded")),
        ("results", Json::Arr(rows)),
    ]);
    std::fs::create_dir_all("bench_results").expect("creating bench_results/");
    std::fs::write("bench_results/cluster_scale.json", doc.to_string())
        .expect("writing bench_results/cluster_scale.json");
    println!("cluster sweep JSON -> bench_results/cluster_scale.json");

    distributed_sweep(&b, samples);
}
