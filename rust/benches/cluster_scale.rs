//! Cluster-scale sweep: replicas × sessions (§Scale, the replica tier's
//! acceptance exhibit).
//!
//! For each cell the bench serves the same deterministic fleet —
//! heterogeneous per-session uplinks, one μLinUCB learner per session —
//! through the replica cluster at 1/2/4 replicas and reports frames/sec
//! plus the fleet mean delay.  Replication is a *simulated-capacity*
//! axis, not a wall-clock one: more replicas means more edge executors
//! sharing the fleet (lower contention, lower delay), while the serving
//! work per frame stays the same, so frames/sec mainly tracks router +
//! per-replica bookkeeping overhead.  The cluster is bit-identical at
//! every worker count (pinned in `rust/tests/cluster.rs`), so none of
//! this sweep is behaviour drift.
//!
//! Results land in `bench_results/cluster_scale.json`; CI runs the
//! sweep in smoke mode (`BENCH_SAMPLES=3`) and uploads the artifact
//! alongside the other bench JSONs.

use ans::bandit;
use ans::coordinator::cluster::{Cluster, ClusterConfig, Placement, ReplicaSpec};
use ans::coordinator::engine::EngineConfig;
use ans::coordinator::FrameSource;
use ans::models::zoo;
use ans::simulator::{scenario, Contention, Workload, DEVICE_MAXN, EDGE_GPU};
use ans::util::bench::Bench;
use ans::util::json::{obj, Json};
use std::time::Instant;

const REPLICAS: &[usize] = &[1, 2, 4];
const SESSIONS: &[usize] = &[64, 256];
/// Total session-frames per run, held roughly constant across fleet
/// sizes so every cell does comparable work.
const FRAME_BUDGET: usize = 20_000;

fn build_cluster(sessions: usize, replicas: usize, placement: Placement) -> Cluster {
    let net = zoo::partnet();
    let rounds = (FRAME_BUDGET / sessions).max(20);
    let mut cl = Cluster::new(
        ClusterConfig::new(
            EngineConfig {
                contention: Contention::new(2, 0.25),
                ingress_mbps: Some(400.0),
                ..Default::default()
            },
            placement,
            50,
        ),
        ReplicaSpec::uniform(replicas, EDGE_GPU, Workload::constant(1.0)),
    );
    for env in scenario::fleet(net.clone(), sessions, 12.0, 7) {
        let policy =
            bandit::by_name("mu-linucb", &net, &DEVICE_MAXN, &EDGE_GPU, rounds, None, None)
                .expect("known policy");
        cl.add_session(policy, env, FrameSource::uniform());
    }
    cl
}

/// Serve the scenario once; returns (frames/sec, fleet mean delay ms).
fn serve_once(sessions: usize, replicas: usize, placement: Placement) -> (f64, f64) {
    let rounds = (FRAME_BUDGET / sessions).max(20);
    let mut cl = build_cluster(sessions, replicas, placement);
    let start = Instant::now();
    cl.run(rounds);
    let secs = start.elapsed().as_secs_f64();
    let fs = cl.fleet_summary();
    ((sessions * rounds) as f64 / secs.max(1e-9), fs.aggregate.mean_delay_ms)
}

fn main() {
    let b = Bench::from_env();
    let samples = b.samples.max(1);
    println!("cluster_scale: {} sample(s) per cell", samples);

    let mut rows: Vec<Json> = Vec::new();
    for &sessions in SESSIONS {
        let name = format!("cluster_scale/s{sessions}");
        if !b.enabled(&name) {
            continue;
        }
        let mut base_fps = 0.0;
        for &replicas in REPLICAS {
            // Best-of-samples frames/sec (least-noisy machine estimate);
            // the mean delay is deterministic across samples.
            let mut best = 0.0_f64;
            let mut mean_delay = f64::NAN;
            for _ in 0..samples {
                let (fps, delay) = serve_once(sessions, replicas, Placement::LeastLoaded);
                best = best.max(fps);
                mean_delay = delay;
            }
            if replicas == 1 {
                base_fps = best;
            }
            let relative = if base_fps > 0.0 { best / base_fps } else { 1.0 };
            println!(
                "{name:<32} replicas {replicas}  {best:>12.0} frames/s  (x{relative:.2} vs 1 \
                 replica)  fleet mean {mean_delay:>8.1} ms"
            );
            rows.push(obj(vec![
                ("sessions", Json::from(sessions)),
                ("replicas", Json::from(replicas)),
                ("frames_per_sec", Json::from(best)),
                ("throughput_vs_1_replica", Json::from(relative)),
                ("mean_delay_ms", Json::from(mean_delay)),
            ]));
        }
    }

    let doc = obj(vec![
        ("bench", Json::from("cluster_scale")),
        ("samples", Json::from(samples)),
        ("frame_budget", Json::from(FRAME_BUDGET)),
        ("placement", Json::from("least-loaded")),
        ("results", Json::Arr(rows)),
    ]);
    std::fs::create_dir_all("bench_results").expect("creating bench_results/");
    std::fs::write("bench_results/cluster_scale.json", doc.to_string())
        .expect("writing bench_results/cluster_scale.json");
    println!("cluster sweep JSON -> bench_results/cluster_scale.json");
}
