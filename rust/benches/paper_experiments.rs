//! `cargo bench` target regenerating every table and figure of the paper's
//! evaluation (DESIGN.md §5).  One exhibit per paper artifact; pass a
//! substring filter to run a subset, e.g. `cargo bench --bench
//! paper_experiments -- fig11`.  CSVs land in `bench_results/`.

fn main() {
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_else(|| "all".to_string());
    if let Err(e) = ans::coordinator::exhibits::run_all(&filter) {
        eprintln!("exhibits failed: {e:#}");
        std::process::exit(1);
    }
}
