//! Open-world fleet throughput (§Perf, ISSUE 9 acceptance): admissions
//! per second and frames per second at 10k and 100k LIVE sessions with a
//! ~1% duty cycle (period 100, one-round bursts), against one engine.
//!
//! The exhibit is the O(active) claim: a steady open-world round costs
//! proportional to the sessions currently on-burst, never the live
//! population — off-duty sessions are hibernated byte arenas, and the
//! engine's active-set index skips idle residents.  The bench pins the
//! claim directly: a 100 000-live open-world round (~1 000 active) must
//! be CHEAPER than a 10 000-session all-active closed-world round, i.e.
//! 10x the population serves faster because only 1% of it is awake.
//!
//! Results go to `bench_results/openworld.json`; CI runs the bench in
//! smoke mode (`BENCH_SAMPLES=3`) and uploads the artifact.  The
//! hibernation/zero-alloc churn audit lives in `benches/hotpath.rs`;
//! bit-identity of churn under sharding is pinned in `rust/tests/`.

use ans::bandit;
use ans::coordinator::engine::{Engine, EngineConfig};
use ans::coordinator::openworld::SessionBuilder;
use ans::coordinator::{FrameSource, OpenWorld};
use ans::models::zoo;
use ans::simulator::scenario::ChurnSchedule;
use ans::simulator::{scenario, Contention, DEVICE_MAXN, EDGE_GPU};
use ans::util::bench::Bench;
use ans::util::json::{obj, Json};
use std::time::Instant;

/// Duty-cycle period: each session is on-burst 1 round in 100 (~1%).
const PERIOD: usize = 100;
/// Mean lifespan in rounds — far beyond the bench horizon, so the
/// timed window measures duty churn (hibernate/wake), not departures.
const LIFESPAN: usize = 10_000;
const SEED: u64 = 90;

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        contention: Contention::new(2, 0.25),
        ingress_mbps: Some(400.0),
        workers: 1,
        ..Default::default()
    }
}

fn builder() -> SessionBuilder {
    let net = zoo::partnet();
    Box::new(move |g| {
        let env = scenario::fleet_session(net.clone(), g, 12.0, DEVICE_MAXN, EDGE_GPU, 1.0, SEED);
        let policy = bandit::by_name("mu-linucb", &net, &DEVICE_MAXN, &EDGE_GPU, 1_000, None, None)
            .expect("known policy");
        (policy, env, FrameSource::uniform())
    })
}

struct Cell {
    live: usize,
    admissions_per_sec: f64,
    rounds_per_sec: f64,
    frames_per_sec: f64,
    round_ms: f64,
    active: usize,
    resident: usize,
    cold: usize,
    cold_bytes: usize,
}

/// Admit `live` sessions (timed), settle, then time one full duty
/// period of steady churn rounds.  Returns the best sample.
fn openworld_cell(live: usize, samples: usize) -> Cell {
    let mut best: Option<Cell> = None;
    for _ in 0..samples {
        let schedule = ChurnSchedule::new(SEED, live, 0.5, LIFESPAN, 0.01).with_period(PERIOD);
        let start = Instant::now();
        let mut world = OpenWorld::new(engine_cfg(), schedule, builder());
        let adm_secs = start.elapsed().as_secs_f64();

        world.run(10); // settle caches and the first wake cohorts
        let s0 = world.stats();
        let start = Instant::now();
        world.run(PERIOD);
        let secs = start.elapsed().as_secs_f64().max(1e-9);
        let s1 = world.stats();

        let frames = (s1.frames - s0.frames) as f64;
        let cell = Cell {
            live: s1.live,
            admissions_per_sec: live as f64 / adm_secs.max(1e-9),
            rounds_per_sec: PERIOD as f64 / secs,
            frames_per_sec: frames / secs,
            round_ms: secs * 1e3 / PERIOD as f64,
            active: s1.active,
            resident: s1.resident,
            cold: s1.cold,
            cold_bytes: s1.cold_bytes,
        };
        // Residency must track the active set, not the population.
        assert!(
            cell.active >= live / (2 * PERIOD) && cell.active <= 2 * live / PERIOD,
            "live {live}: steady active {} should be ~{}",
            cell.active,
            live / PERIOD
        );
        assert!(
            cell.resident < live / 10,
            "live {live}: {} resident — off-duty sessions must be cold, not resident",
            cell.resident
        );
        if best.as_ref().map_or(true, |b| cell.round_ms < b.round_ms) {
            best = Some(cell);
        }
    }
    best.expect("at least one sample")
}

/// Closed-world reference: `sessions` all-active μLinUCB sessions on
/// the same engine configuration.  Returns best-of-samples round ms.
fn closed_round_ms(sessions: usize, samples: usize) -> f64 {
    const TIMED: usize = 5;
    let mut best = f64::INFINITY;
    for _ in 0..samples {
        let net = zoo::partnet();
        let mut eng = Engine::new(engine_cfg());
        let mut build = builder();
        for g in 0..sessions as u64 {
            let (policy, env, source) = build(g);
            eng.add_session(policy, env, source);
        }
        eng.reserve(2 + TIMED);
        eng.run(2);
        let start = Instant::now();
        eng.run(TIMED);
        best = best.min(start.elapsed().as_secs_f64() * 1e3 / TIMED as f64);
    }
    best
}

fn main() {
    let b = Bench::from_env();
    let samples = b.samples.max(1);
    println!("openworld: {} sample(s) per cell, duty 1% (period {PERIOD})", samples);

    let mut rows: Vec<Json> = Vec::new();
    let mut cells: Vec<(usize, Cell)> = Vec::new();
    for live in [10_000usize, 100_000] {
        let name = format!("openworld/live{live}");
        if !b.enabled(&name) {
            continue;
        }
        let cell = openworld_cell(live, samples);
        println!(
            "{name:<28} {:>10.0} admissions/s  {:>9.0} frames/s  {:>8.3} ms/round  \
             active {:>5}  resident {:>6}  cold {:>6} ({} KiB)",
            cell.admissions_per_sec,
            cell.frames_per_sec,
            cell.round_ms,
            cell.active,
            cell.resident,
            cell.cold,
            cell.cold_bytes / 1024,
        );
        rows.push(obj(vec![
            ("live", Json::from(cell.live)),
            ("period", Json::from(PERIOD)),
            ("active", Json::from(cell.active)),
            ("resident", Json::from(cell.resident)),
            ("cold", Json::from(cell.cold)),
            ("cold_bytes", Json::from(cell.cold_bytes)),
            ("admissions_per_sec", Json::from(cell.admissions_per_sec)),
            ("rounds_per_sec", Json::from(cell.rounds_per_sec)),
            ("frames_per_sec", Json::from(cell.frames_per_sec)),
            ("round_ms", Json::from(cell.round_ms)),
        ]));
        cells.push((live, cell));
    }

    // The acceptance exhibit: 100k live at 1% duty vs 10k all-active.
    // (-1 when the 100k cell is filtered out via BENCH_FILTER.)
    let mut baseline_ms = -1.0;
    if let Some((_, big)) = cells.iter().find(|(live, _)| *live == 100_000) {
        baseline_ms = closed_round_ms(10_000, samples);
        println!(
            "openworld/exhibit            100k-live round {:.3} ms vs 10k-all-active {:.3} ms",
            big.round_ms, baseline_ms
        );
        assert!(
            big.round_ms < baseline_ms,
            "O(active) regression: a 100k-live 1%-duty round ({:.3} ms) must beat a \
             10k-session all-active round ({:.3} ms)",
            big.round_ms,
            baseline_ms
        );
    }

    let doc = obj(vec![
        ("bench", Json::from("openworld")),
        ("samples", Json::from(samples)),
        ("period", Json::from(PERIOD)),
        ("mean_lifespan", Json::from(LIFESPAN)),
        ("closed_10k_round_ms", Json::from(baseline_ms)),
        ("results", Json::Arr(rows)),
    ]);
    std::fs::create_dir_all("bench_results").expect("creating bench_results/");
    std::fs::write("bench_results/openworld.json", doc.to_string())
        .expect("writing bench_results/openworld.json");
    println!("open-world throughput JSON -> bench_results/openworld.json");
}
