//! Fleet-scale throughput sweep: workers × sessions (§Perf, the sharded
//! engine's acceptance exhibit).
//!
//! For each scenario the bench serves the same deterministic fleet —
//! heterogeneous per-session uplinks into one contended edge, one
//! μLinUCB learner per session — through the engine at 1/2/4/8 workers
//! and reports frames/sec plus speedup vs the 1-worker baseline.  The
//! sharded engine is bit-identical at every worker count (pinned in
//! `rust/tests/fleet.rs`), so this sweep measures *only* wall-clock
//! scaling, never behaviour drift.
//!
//! Results append to `bench_results/fleet_scale.json` so the perf
//! trajectory is tracked from this PR on; CI runs the sweep in smoke
//! mode (`BENCH_SAMPLES=3`) and uploads the artifact.  Speedups are
//! hardware-bound: a W-worker sweep cannot beat the host's core count
//! (recorded as `host_cores` in the artifact).

use ans::bandit;
use ans::coordinator::engine::{Engine, EngineConfig};
use ans::coordinator::FrameSource;
use ans::edge::{AdmissionPolicy, SchedulerConfig};
use ans::models::zoo;
use ans::simulator::{scenario, Contention, DEVICE_MAXN, EDGE_GPU};
use ans::util::bench::Bench;
use ans::util::json::{obj, Json};
use std::time::Instant;

const WORKERS: &[usize] = &[1, 2, 4, 8];
const SESSIONS: &[usize] = &[16, 64, 256];
/// Total session-frames per run, held roughly constant across fleet
/// sizes so every cell does comparable work.
const FRAME_BUDGET: usize = 40_000;

fn build_engine(sessions: usize, workers: usize, scheduler: SchedulerConfig) -> Engine {
    let net = zoo::partnet();
    let mut eng = Engine::new(EngineConfig {
        contention: Contention::new(2, 0.25),
        ingress_mbps: Some(400.0),
        scheduler,
        workers,
        ..Default::default()
    });
    let rounds = (FRAME_BUDGET / sessions).max(20);
    for env in scenario::fleet(net.clone(), sessions, 12.0, 7) {
        let policy =
            bandit::by_name("mu-linucb", &net, &DEVICE_MAXN, &EDGE_GPU, rounds, None, None)
                .expect("known policy");
        eng.add_session(policy, env, FrameSource::uniform());
    }
    eng
}

/// Serve the scenario once; returns frames/sec over the timed run.
fn serve_once(sessions: usize, workers: usize, scheduler: &SchedulerConfig) -> f64 {
    let rounds = (FRAME_BUDGET / sessions).max(20);
    let mut eng = build_engine(sessions, workers, scheduler.clone());
    let start = Instant::now();
    eng.run(rounds);
    let secs = start.elapsed().as_secs_f64();
    (sessions * rounds) as f64 / secs.max(1e-9)
}

fn main() {
    let b = Bench::from_env();
    let samples = b.samples.max(1);
    let host_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "fleet_scale: {} sample(s) per cell, host has {} core(s); speedup is bounded by cores",
        samples, host_cores
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut sweep = |label: &str, scheduler: SchedulerConfig, sessions_list: &[usize]| {
        for &sessions in sessions_list {
            let name = format!("fleet_scale/{label}_s{sessions}");
            if !b.enabled(&name) {
                continue;
            }
            let mut base_fps = 0.0;
            for &workers in WORKERS {
                // Best-of-samples: throughput benches want the least
                // noisy estimate of the machine's capability.
                let mut best = 0.0_f64;
                for _ in 0..samples {
                    best = best.max(serve_once(sessions, workers, &scheduler));
                }
                if workers == 1 {
                    base_fps = best;
                }
                let speedup = if base_fps > 0.0 { best / base_fps } else { 1.0 };
                println!(
                    "{name:<40} workers {workers}  {best:>12.0} frames/s  speedup x{speedup:.2}"
                );
                rows.push(obj(vec![
                    ("scenario", Json::from(label)),
                    ("sessions", Json::from(sessions)),
                    ("workers", Json::from(workers)),
                    ("frames_per_sec", Json::from(best)),
                    ("speedup_vs_1_worker", Json::from(speedup)),
                ]));
            }
        }
    };

    // The dense per-frame path (lockstep rounds) is the scaling story;
    // one event-driven cell shows the scheduler path scales too.
    sweep("lockstep", SchedulerConfig::lockstep_fifo(), SESSIONS);
    let mut edf = SchedulerConfig::event(AdmissionPolicy::Edf);
    edf.batch_window_ms = 4.0;
    sweep("edf_batched", edf, &[64]);

    let doc = obj(vec![
        ("bench", Json::from("fleet_scale")),
        ("host_cores", Json::from(host_cores)),
        ("samples", Json::from(samples)),
        ("frame_budget", Json::from(FRAME_BUDGET)),
        ("results", Json::Arr(rows)),
    ]);
    std::fs::create_dir_all("bench_results").expect("creating bench_results/");
    std::fs::write("bench_results/fleet_scale.json", doc.to_string())
        .expect("writing bench_results/fleet_scale.json");
    println!("scaling sweep JSON -> bench_results/fleet_scale.json");
}
