//! Fleet-scale throughput sweep: workers × sessions (§Perf, the sharded
//! engine's acceptance exhibit).
//!
//! For each scenario the bench serves the same deterministic fleet —
//! heterogeneous per-session uplinks into one contended edge, one
//! μLinUCB learner per session — through the engine at 1/2/4/8 workers
//! and reports frames/sec plus speedup vs the 1-worker baseline.  The
//! sharded engine is bit-identical at every worker count (pinned in
//! `rust/tests/fleet.rs`), so this sweep measures *only* wall-clock
//! scaling, never behaviour drift.
//!
//! Results append to `bench_results/fleet_scale.json` so the perf
//! trajectory is tracked from this PR on; CI runs the sweep in smoke
//! mode (`BENCH_SAMPLES=3`) and uploads the artifact.  Speedups are
//! hardware-bound: a W-worker sweep cannot beat the host's core count
//! (recorded as `host_cores` in the artifact).

use ans::bandit;
use ans::bandit::linalg::RidgeState;
use ans::bandit::PolicyStore;
use ans::coordinator::engine::{Engine, EngineConfig, SelectBatch};
use ans::coordinator::FrameSource;
use ans::edge::{AdmissionPolicy, SchedulerConfig};
use ans::models::{zoo, CONTEXT_DIM};
use ans::simulator::{scenario, Contention, DEVICE_MAXN, EDGE_GPU};
use ans::util::bench::Bench;
use ans::util::json::{obj, Json};
use ans::util::rng::Rng;
use std::time::Instant;

const WORKERS: &[usize] = &[1, 2, 4, 8];
const SESSIONS: &[usize] = &[16, 64, 256];
/// Total session-frames per run, held roughly constant across fleet
/// sizes so every cell does comparable work.
const FRAME_BUDGET: usize = 40_000;

fn build_engine(sessions: usize, workers: usize, scheduler: SchedulerConfig) -> Engine {
    let net = zoo::partnet();
    let mut eng = Engine::new(EngineConfig {
        contention: Contention::new(2, 0.25),
        ingress_mbps: Some(400.0),
        scheduler,
        workers,
        ..Default::default()
    });
    let rounds = (FRAME_BUDGET / sessions).max(20);
    for env in scenario::fleet(net.clone(), sessions, 12.0, 7) {
        let policy =
            bandit::by_name("mu-linucb", &net, &DEVICE_MAXN, &EDGE_GPU, rounds, None, None)
                .expect("known policy");
        eng.add_session(policy, env, FrameSource::uniform());
    }
    eng
}

/// Serve the scenario once; returns frames/sec over the timed run.
fn serve_once(sessions: usize, workers: usize, scheduler: &SchedulerConfig) -> f64 {
    let rounds = (FRAME_BUDGET / sessions).max(20);
    let mut eng = build_engine(sessions, workers, scheduler.clone());
    let start = Instant::now();
    eng.run(rounds);
    let secs = start.elapsed().as_secs_f64();
    (sessions * rounds) as f64 / secs.max(1e-9)
}

fn main() {
    let b = Bench::from_env();
    let samples = b.samples.max(1);
    let host_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "fleet_scale: {} sample(s) per cell, host has {} core(s); speedup is bounded by cores",
        samples, host_cores
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut sweep = |label: &str, scheduler: SchedulerConfig, sessions_list: &[usize]| {
        for &sessions in sessions_list {
            let name = format!("fleet_scale/{label}_s{sessions}");
            if !b.enabled(&name) {
                continue;
            }
            let mut base_fps = 0.0;
            for &workers in WORKERS {
                // Best-of-samples: throughput benches want the least
                // noisy estimate of the machine's capability.
                let mut best = 0.0_f64;
                for _ in 0..samples {
                    best = best.max(serve_once(sessions, workers, &scheduler));
                }
                if workers == 1 {
                    base_fps = best;
                }
                let speedup = if base_fps > 0.0 { best / base_fps } else { 1.0 };
                println!(
                    "{name:<40} workers {workers}  {best:>12.0} frames/s  speedup x{speedup:.2}"
                );
                rows.push(obj(vec![
                    ("scenario", Json::from(label)),
                    ("sessions", Json::from(sessions)),
                    ("workers", Json::from(workers)),
                    ("frames_per_sec", Json::from(best)),
                    ("speedup_vs_1_worker", Json::from(speedup)),
                ]));
            }
        }
    };

    // The dense per-frame path (lockstep rounds) is the scaling story;
    // one event-driven cell shows the scheduler path scales too.
    sweep("lockstep", SchedulerConfig::lockstep_fifo(), SESSIONS);
    let mut edf = SchedulerConfig::event(AdmissionPolicy::Edf);
    edf.batch_window_ms = 4.0;
    sweep("edf_batched", edf, &[64]);

    let doc = obj(vec![
        ("bench", Json::from("fleet_scale")),
        ("host_cores", Json::from(host_cores)),
        ("samples", Json::from(samples)),
        ("frame_budget", Json::from(FRAME_BUDGET)),
        ("results", Json::Arr(rows)),
    ]);
    std::fs::create_dir_all("bench_results").expect("creating bench_results/");
    std::fs::write("bench_results/fleet_scale.json", doc.to_string())
        .expect("writing bench_results/fleet_scale.json");
    println!("scaling sweep JSON -> bench_results/fleet_scale.json");

    policy_soa(&b, samples, host_cores);
    select_armmajor(&b, samples, host_cores);
}

/// End-to-end arm-major vs session-major select (ISSUE 8 acceptance):
/// the SAME 256-session μLinUCB lockstep scenario served twice through
/// the full engine — once with `--select-batch off` (the scalar
/// per-session path) and once with `--select-batch on` (the arm-major
/// batched store kernels).  The two paths are pinned bit-identical
/// (`rust/tests/fleet.rs`), re-asserted here via a transcript checksum,
/// so the ratio is purely the layout/loop-order effect carried into
/// frames/sec.
fn select_armmajor(b: &Bench, samples: usize, host_cores: usize) {
    const N: usize = 256; // the fleet_scale acceptance cell
    let name = "select_armmajor/on_vs_off_s256";
    if !b.enabled(name) {
        return;
    }
    let rounds = (FRAME_BUDGET / N).max(20);

    // Serve once in the given mode; returns (frames/sec, transcript
    // checksum over every session's (p, delay bits, wait bits)).
    let serve_mode = |mode: SelectBatch| -> (f64, u64) {
        let net = zoo::partnet();
        let mut eng = Engine::new(EngineConfig {
            contention: Contention::new(2, 0.25),
            ingress_mbps: Some(400.0),
            select_batch: mode,
            ..Default::default()
        });
        for env in scenario::fleet(net.clone(), N, 12.0, 7) {
            let policy =
                bandit::by_name("mu-linucb", &net, &DEVICE_MAXN, &EDGE_GPU, rounds, None, None)
                    .expect("known policy");
            eng.add_session(policy, env, FrameSource::uniform());
        }
        assert_eq!(eng.select_batch_effective(), mode.name());
        eng.reserve(rounds);
        let start = Instant::now();
        eng.run(rounds);
        let secs = start.elapsed().as_secs_f64();
        let mut sum = 0u64;
        for s in eng.sessions() {
            for r in &s.metrics.records {
                sum = sum
                    .wrapping_add(r.p as u64)
                    .wrapping_add(r.delay_ms.to_bits())
                    .wrapping_add(r.queue_wait_ms.to_bits());
            }
        }
        ((N * rounds) as f64 / secs.max(1e-9), sum)
    };

    let mut off_fps = 0.0_f64;
    let mut on_fps = 0.0_f64;
    let mut off_sum = 0u64;
    let mut on_sum = 0u64;
    for _ in 0..samples {
        let (f, c) = serve_mode(SelectBatch::Off);
        off_fps = off_fps.max(f);
        off_sum = c;
        let (f, c) = serve_mode(SelectBatch::On);
        on_fps = on_fps.max(f);
        on_sum = c;
    }
    assert_eq!(
        off_sum, on_sum,
        "arm-major and scalar select must serve bit-identical transcripts"
    );
    let speedup = on_fps / off_fps.max(1e-9);
    println!(
        "{name:<40} off {off_fps:>12.0} f/s   on {on_fps:>12.0} f/s   speedup x{speedup:.2}"
    );

    let doc = obj(vec![
        ("bench", Json::from("select_armmajor")),
        ("host_cores", Json::from(host_cores)),
        ("samples", Json::from(samples)),
        ("sessions", Json::from(N)),
        ("rounds", Json::from(rounds)),
        ("transcript_checksum", Json::from(format!("{on_sum:016x}"))),
        ("session_major_frames_per_sec", Json::from(off_fps)),
        ("arm_major_frames_per_sec", Json::from(on_fps)),
        ("speedup", Json::from(speedup)),
    ]);
    std::fs::write("bench_results/select_armmajor.json", doc.to_string())
        .expect("writing bench_results/select_armmajor.json");
    println!("arm-major select comparison JSON -> bench_results/select_armmajor.json");
}

/// Scalar-vs-SoA comparison of the cross-session policy math itself:
/// per round every session scores every arm (predict + confidence) and
/// absorbs one observation.  Both routes run the SAME slice kernels in
/// the SAME per-session op order — decisions are asserted identical via
/// checksum — so the ratio isolates the layout effect: boxed per-session
/// `RidgeState`s chased through pointers vs one flat arena walked
/// arm-major with `chunks_exact` strides.
fn policy_soa(b: &Bench, samples: usize, host_cores: usize) {
    const N: usize = 256; // sessions — the fleet_scale acceptance cell
    const ROUNDS: usize = 300;
    const ARMS: usize = 22; // VGG16-scale partition-point count
    const D: usize = CONTEXT_DIM;
    let name = "policy_soa/scalar_vs_soa_s256";
    if !b.enabled(name) {
        return;
    }
    let alpha = 1.0;
    let beta = 1.0;

    // Shared inputs: one context per arm, its N-fold tiling for the
    // batch kernels, and one observation per (round, session).
    let mut rng = Rng::new(0xBA7C4);
    let ctxs: Vec<Vec<f64>> = (0..ARMS)
        .map(|_| (0..D).map(|_| rng.uniform(0.0, 1.0)).collect())
        .collect();
    let tiled: Vec<Vec<f64>> = ctxs
        .iter()
        .map(|x| (0..N).flat_map(|_| x.iter().copied()).collect())
        .collect();
    let ys: Vec<f64> = (0..ROUNDS * N).map(|_| rng.uniform(5.0, 250.0)).collect();

    // Array-of-structs baseline: one heap RidgeState per session,
    // session-major iteration.
    let run_scalar = || -> (f64, u64) {
        let mut sts: Vec<RidgeState> = (0..N).map(|_| RidgeState::new(D, beta)).collect();
        let mut sum = 0u64;
        let start = Instant::now();
        for r in 0..ROUNDS {
            for (s, st) in sts.iter_mut().enumerate() {
                let mut best = f64::INFINITY;
                let mut bp = 0usize;
                for (p, x) in ctxs.iter().enumerate() {
                    let score = st.predict(x) - alpha * st.confidence_sq(x).sqrt();
                    if score < best {
                        best = score;
                        bp = p;
                    }
                }
                st.update(&ctxs[bp], ys[r * N + s]);
                sum = sum.wrapping_add(bp as u64);
            }
        }
        ((N * ROUNDS) as f64 / start.elapsed().as_secs_f64().max(1e-9), sum)
    };

    // Structure-of-arrays: the engine's policy store, arm-major batched
    // predict/confidence over the packed arenas, then one batched
    // Sherman–Morrison update (per-session op order unchanged).
    let run_soa = || -> (f64, u64) {
        let mut store = PolicyStore::with_capacity(D, N);
        let prior = RidgeState::new(D, beta);
        for i in 0..N {
            store.push_slot();
            store.slot_mut(i).load_from(&prior);
        }
        let mut pred = vec![0.0; N];
        let mut conf = vec![0.0; N];
        let mut best = vec![f64::INFINITY; N];
        let mut bp = vec![0usize; N];
        let mut xs_sel = vec![0.0; N * D];
        let mut ys_sel = vec![0.0; N];
        let mut sum = 0u64;
        let start = Instant::now();
        for r in 0..ROUNDS {
            best.iter_mut().for_each(|v| *v = f64::INFINITY);
            for (p, tx) in tiled.iter().enumerate() {
                store.predict_batch(tx, &mut pred);
                store.confidence_batch(tx, &mut conf);
                for s in 0..N {
                    let score = pred[s] - alpha * conf[s].sqrt();
                    if score < best[s] {
                        best[s] = score;
                        bp[s] = p;
                    }
                }
            }
            for s in 0..N {
                xs_sel[s * D..(s + 1) * D].copy_from_slice(&ctxs[bp[s]]);
                ys_sel[s] = ys[r * N + s];
                sum = sum.wrapping_add(bp[s] as u64);
            }
            store.update_batch(&xs_sel, &ys_sel);
        }
        ((N * ROUNDS) as f64 / start.elapsed().as_secs_f64().max(1e-9), sum)
    };

    let mut scalar_fps = 0.0_f64;
    let mut soa_fps = 0.0_f64;
    let mut scalar_sum = 0u64;
    let mut soa_sum = 0u64;
    for _ in 0..samples {
        let (f, c) = run_scalar();
        scalar_fps = scalar_fps.max(f);
        scalar_sum = c;
        let (f, c) = run_soa();
        soa_fps = soa_fps.max(f);
        soa_sum = c;
    }
    assert_eq!(
        scalar_sum, soa_sum,
        "scalar and SoA routes must pick identical arms — same kernels, same op order"
    );
    let speedup = soa_fps / scalar_fps.max(1e-9);
    println!(
        "{name:<40} scalar {scalar_fps:>12.0} f/s   soa {soa_fps:>12.0} f/s   speedup x{speedup:.2}"
    );

    let doc = obj(vec![
        ("bench", Json::from("policy_soa")),
        ("host_cores", Json::from(host_cores)),
        ("samples", Json::from(samples)),
        ("sessions", Json::from(N)),
        ("rounds", Json::from(ROUNDS)),
        ("arms", Json::from(ARMS)),
        ("scalar_frames_per_sec", Json::from(scalar_fps)),
        ("soa_frames_per_sec", Json::from(soa_fps)),
        ("speedup", Json::from(speedup)),
    ]);
    std::fs::write("bench_results/policy_soa.json", doc.to_string())
        .expect("writing bench_results/policy_soa.json");
    println!("policy SoA comparison JSON -> bench_results/policy_soa.json");
}
