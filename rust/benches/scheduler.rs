//! Edge-scheduler hot paths: queue submit/drain per policy, batch
//! formation, and a full engine round in lockstep vs event mode — the
//! per-frame scheduling overhead must stay negligible next to inference
//! (the same bar §3.2 of the paper sets for μLinUCB).  Custom harness
//! (criterion is unavailable offline); see `ans::util::bench`.

use ans::bandit;
use ans::coordinator::engine::{Engine, EngineConfig};
use ans::coordinator::FrameSource;
use ans::edge::{AdmissionPolicy, EdgeJob, EdgeQueue, QueueConfig, SchedulerConfig};
use ans::models::zoo;
use ans::simulator::{scenario, Contention, DEVICE_MAXN, EDGE_GPU};
use ans::util::bench::Bench;

fn job(session: usize, p: usize, arrival: f64, solo: f64) -> EdgeJob {
    EdgeJob {
        session,
        p,
        bytes: 12_288,
        capture_ms: arrival,
        arrival_ms: arrival,
        deadline_ms: arrival + 50.0,
        weight: 0.2,
        solo_ms: solo,
        seq: 0,
    }
}

fn bench_queue(b: &mut Bench, name: &str, policy: AdmissionPolicy, max_batch: usize) {
    let mut cfg = QueueConfig::new(policy, Contention::new(1, 0.25));
    cfg.max_batch = max_batch;
    cfg.batch_window_ms = if max_batch > 1 { 8.0 } else { 0.0 };
    let mut queue = EdgeQueue::new(cfg);
    let mut round = 0u64;
    b.run(name, || {
        // One contended fleet round: 16 concurrent offloads, 4 splits.
        let base = round as f64 * 33.3;
        round += 1;
        for s in 0..16 {
            queue.submit(job(s, s % 4, base + s as f64 * 0.7, 5.0));
        }
        queue.drain().len()
    });
}

fn engine_round(scheduler: SchedulerConfig) -> Engine {
    let net = zoo::partnet();
    let mut eng = Engine::new(EngineConfig {
        contention: Contention::new(1, 0.25),
        scheduler,
        ..Default::default()
    });
    for env in scenario::fleet(net.clone(), 8, 10.0, 3) {
        let policy =
            bandit::by_name("mu-linucb", &net, &DEVICE_MAXN, &EDGE_GPU, 100_000, None, None)
                .unwrap();
        eng.add_session(policy, env, FrameSource::uniform());
    }
    eng
}

fn main() {
    let mut b = Bench::from_env().with_samples(40);

    bench_queue(&mut b, "queue/fifo_16_jobs_no_batch", AdmissionPolicy::Fifo, 1);
    bench_queue(&mut b, "queue/edf_16_jobs_batch8", AdmissionPolicy::Edf, 8);
    bench_queue(&mut b, "queue/wfair_16_jobs_batch8", AdmissionPolicy::WeightedFair, 8);

    // Full engine rounds: the lockstep fast path vs the event queue.
    let mut lockstep = engine_round(SchedulerConfig::lockstep_fifo());
    b.run("engine/8_session_round_lockstep", || lockstep.step());
    let mut event = engine_round(SchedulerConfig::event(AdmissionPolicy::Edf));
    b.run("engine/8_session_round_event_edf", || event.step());

    b.write_csv("scheduler.csv").expect("writing bench_results/scheduler.csv");
}
