//! Ablation benches: the design choices DESIGN.md calls out, each
//! toggled independently and measured on the two canonical workloads —
//! stationary (Vgg16 @ 16 Mbps, 600 frames) and the Fig 12(a) adaptation
//! trace (800 frames).  Output: mean expected delay (lower is better) and
//! final-phase oracle tracking.  Run: `cargo bench --bench ablations`.

use ans::bandit::policy::{FrameContext, Privileged};
use ans::bandit::{LinUcb, Policy, DEFAULT_ALPHA, DEFAULT_BETA, DEFAULT_DRIFT};
use ans::models::{features, zoo, FeatureScale, CONTEXT_DIM};
use ans::simulator::{scenario, Environment};

/// Drive a policy; returns (mean expected delay, final-100 oracle-match %).
fn run(pol: &mut dyn Policy, env: &mut Environment, frames: usize) -> (f64, f64) {
    let scale = FeatureScale::for_network(&env.net);
    let contexts = features::context_vectors(&env.net, &scale);
    let front: Vec<f64> = env.front_delays().to_vec();
    let p_max = env.num_partitions();
    let mut total = 0.0;
    let mut tail_hits = 0usize;
    for t in 0..frames {
        env.tick(t);
        let ctx = FrameContext {
            t,
            weight: 0.2,
            front_delays: &front,
            contexts: &contexts,
            queue_wait_ms: &[],
            privileged: Privileged { rate_mbps: env.current_rate_mbps(), expected_totals: None },
        };
        let p = pol.select(&ctx);
        total += env.expected_total(p);
        if p != p_max {
            let d = env.observe_edge_delay(p);
            pol.observe(p, &contexts[p], d);
        }
        if t >= frames - 100 && p == env.oracle_partition() {
            tail_hits += 1;
        }
    }
    (total / frames as f64, tail_hits as f64)
}

fn measure(name: &str, mk: &dyn Fn(usize) -> Box<dyn Policy>) {
    let mut stat_pol = mk(600);
    let (stat, stat_hits) = run(stat_pol.as_mut(), &mut Environment::simple(zoo::vgg16(), 16.0, 1), 600);
    let mut adapt_pol = mk(800);
    let (adapt, adapt_hits) =
        run(adapt_pol.as_mut(), &mut scenario::fig12a(zoo::vgg16(), 5), 800);
    println!(
        "{name:<34} stationary {stat:7.1} ms (tail-match {stat_hits:3.0}%)   fig12a {adapt:7.1} ms (tail-match {adapt_hits:3.0}%)"
    );
}

fn main() {
    println!("ablations over μLinUCB design choices (oracle: stationary 286.4 ms):\n");

    // The full operational configuration.
    measure("ans_default (all features)", &|t| Box::new(LinUcb::ans_default(t)));

    // − drift-reset: Algorithm 1 verbatim.
    measure("- drift_reset (Algorithm 1)", &|t| Box::new(LinUcb::paper_default(t)));

    // − warm-up sweep.
    measure("- warmup sweep", &|t| Box::new(LinUcb::ans_default(t).without_warmup()));

    // − forced sampling (AdaLinUCB: weights only) — trappable.
    measure("- forced sampling (AdaLinUCB)", &|_| {
        Box::new(LinUcb::ada(CONTEXT_DIM, DEFAULT_ALPHA, DEFAULT_BETA).with_drift_reset(DEFAULT_DRIFT))
    });

    // − weights − forcing (classic LinUCB) — the paper's trap case.
    measure("- weights - forcing (LinUCB)", &|_| {
        Box::new(LinUcb::classic(CONTEXT_DIM, DEFAULT_ALPHA, DEFAULT_BETA))
    });

    // Unknown-T phase-doubling schedule instead of known T.
    measure("phase-doubling (unknown T)", &|_| {
        Box::new(
            LinUcb::mu_linucb_unknown_t(CONTEXT_DIM, DEFAULT_ALPHA, DEFAULT_BETA, 0.25, 50)
                .with_drift_reset(DEFAULT_DRIFT)
                .with_auto_scale(),
        )
    });

    // Sliding window instead of drift-reset.
    measure("window(150) instead of drift", &|t| {
        Box::new(LinUcb::paper_default(t).with_window(150))
    });

    // μ sensitivity.
    for mu in [0.1, 0.4] {
        measure(&format!("mu = {mu}"), &|t| {
            Box::new(
                LinUcb::mu_linucb(CONTEXT_DIM, DEFAULT_ALPHA, DEFAULT_BETA, mu, t)
                    .with_drift_reset(DEFAULT_DRIFT)
                    .with_auto_scale(),
            )
        });
    }

    // α sensitivity.
    for alpha in [30.0, 1000.0] {
        measure(&format!("alpha = {alpha}"), &|t| {
            Box::new(
                LinUcb::mu_linucb(CONTEXT_DIM, alpha, DEFAULT_BETA, 0.25, t)
                    .with_drift_reset(DEFAULT_DRIFT)
                    .with_auto_scale(),
            )
        });
    }

    // β sensitivity (the ridge-prior scale analysis of DESIGN.md §4).
    for beta in [1.0, 0.0001] {
        measure(&format!("beta = {beta}"), &|t| {
            Box::new(
                LinUcb::mu_linucb(CONTEXT_DIM, DEFAULT_ALPHA, beta, 0.25, t)
                    .with_drift_reset(DEFAULT_DRIFT)
                    .with_auto_scale(),
            )
        });
    }
}
