//! Hot-path micro-benchmarks (§Perf, L3): the per-frame decision cost of
//! μLinUCB, its components, and the supporting substrates.  The paper's
//! §3.2 complexity analysis claims the per-frame cost is "negligible
//! compared to regular deep inference" — these benches quantify that on
//! this machine.  Custom harness (criterion is unavailable offline); see
//! `ans::util::bench`.

use ans::bandit::linalg::RidgeState;
use ans::bandit::policy::{FrameContext, Privileged};
use ans::bandit::{LinUcb, Policy, PolicyStore};
use ans::coordinator::engine::{Engine, EngineConfig, SelectBatch};
use ans::coordinator::FrameSource;
use ans::edge::{AdmissionPolicy, QueueSignal, SchedulerConfig};
use ans::models::{features, zoo, FeatureScale, CONTEXT_DIM};
use ans::simulator::Contention;
use ans::util::alloc::{allocations, CountingAllocator};
use ans::util::bench::Bench;
use ans::util::rng::Rng;
use ans::video::{ssim, stream::VideoStream};

/// Every allocation in this bench binary is counted, which is what lets
/// the steady-state sections below *assert* zero allocs per frame (the
/// §Perf acceptance bar) instead of merely timing them.
#[global_allocator]
static ALLOC_COUNTER: CountingAllocator = CountingAllocator;

fn main() {
    let mut b = Bench::from_env().with_samples(50);

    // --- the per-frame decision hot path -------------------------------
    let net = zoo::vgg16();
    let scale = FeatureScale::for_network(&net);
    let contexts = features::context_vectors(&net, &scale);
    let front: Vec<f64> = (0..=net.num_partitions()).map(|p| p as f64).collect();
    let mut rng = Rng::new(1);

    let mut pol = LinUcb::paper_default(100_000);
    // Pre-train so the bench measures steady state, not warm-up branches.
    for p in 0..net.num_partitions() {
        pol.observe(p, &contexts[p], rng.uniform(10.0, 500.0));
    }
    let mut t = net.num_partitions() + 1;
    b.run("decide/mu_linucb_select_22_arms", || {
        let ctx = FrameContext {
            t,
            weight: 0.2,
            front_delays: &front,
            contexts: &contexts,
            queue_wait_ms: &[],
            privileged: Privileged { rate_mbps: 16.0, expected_totals: None },
        };
        t += 1;
        pol.select(&ctx)
    });

    let x = contexts[3];
    b.run("decide/observe_update_d7", || {
        pol.observe(3, &x, 123.4);
    });

    // --- linalg substrate ----------------------------------------------
    let mut ridge = RidgeState::new(CONTEXT_DIM, 0.01);
    let xs: Vec<[f64; CONTEXT_DIM]> = (0..64)
        .map(|_| std::array::from_fn(|_| rng.uniform(0.0, 1.0)))
        .collect();
    for v in &xs {
        ridge.update(v, rng.uniform(0.0, 100.0));
    }
    b.run("linalg/sherman_morrison_update", || {
        ridge.update(&xs[0], 42.0);
        ridge.downdate(&xs[0], 42.0);
    });
    b.run("linalg/theta_solve", || ridge.theta());
    b.run("linalg/confidence_quadform", || ridge.confidence_sq(&xs[1]));
    b.run("linalg/cholesky_7x7", || ridge.a.cholesky().unwrap());

    // --- feature construction -------------------------------------------
    b.run("features/context_vectors_vgg16", || features::context_vectors(&net, &scale));

    // --- video substrate -------------------------------------------------
    let mut vs = VideoStream::new(64, 64, 7);
    let a = vs.next_frame();
    let c = vs.next_frame();
    b.run("video/frame_generation_64x64", || vs.next_frame());
    b.run("video/mean_ssim_64x64", || ssim::mean_ssim(&a, &c));

    // --- end-to-end simulated frame -------------------------------------
    let mut env = ans::simulator::Environment::simple(zoo::vgg16(), 16.0, 3);
    let mut pol2 = LinUcb::paper_default(100_000);
    let mut tt = 0usize;
    b.run("frame/full_simulated_frame", || {
        env.tick(tt);
        let ctx = FrameContext {
            t: tt,
            weight: 0.2,
            front_delays: &front,
            contexts: &contexts,
            queue_wait_ms: &[],
            privileged: Privileged { rate_mbps: env.current_rate_mbps(), expected_totals: None },
        };
        let p = pol2.select(&ctx);
        if p != net.num_partitions() {
            let d = env.observe_edge_delay(p);
            pol2.observe(p, &contexts[p], d);
        }
        tt += 1;
        p
    });

    // --- allocation audit ------------------------------------------------
    // The acceptance bar: zero heap allocations per frame on the
    // steady-state select/observe path.  Warm every scratch buffer
    // first, then count allocations across a long run and assert the
    // delta is exactly zero.
    let p_max = net.num_partitions();
    let mut audit_pol = LinUcb::paper_default(1_000_000);
    let mut audit_env = ans::simulator::Environment::simple(zoo::vgg16(), 16.0, 11);
    let frame = |pol: &mut LinUcb, env: &mut ans::simulator::Environment, t: usize| {
        env.tick(t);
        let ctx = FrameContext {
            t,
            weight: 0.2,
            front_delays: &front,
            contexts: &contexts,
            queue_wait_ms: &[],
            privileged: Privileged { rate_mbps: env.current_rate_mbps(), expected_totals: None },
        };
        let p = pol.select(&ctx);
        if p != p_max {
            let d = env.observe_edge_delay(p);
            pol.observe(p, &contexts[p], d);
        }
    };
    for t in 0..256 {
        frame(&mut audit_pol, &mut audit_env, t); // warm-up: fills scratch
    }
    let before = allocations();
    let audit_frames = 4096usize;
    for t in 256..256 + audit_frames {
        frame(&mut audit_pol, &mut audit_env, t);
    }
    let delta = allocations() - before;
    println!(
        "{:<44} {} allocs over {} frames",
        "alloc/select_observe_steady_state", delta, audit_frames
    );
    assert_eq!(delta, 0, "steady-state select/observe must not allocate");

    // Same audit through the full engine round (lockstep, contended,
    // shared ingress — every per-round scratch buffer in play).  Pinned
    // to the scalar per-session path: under the default `auto` an
    // all-μLinUCB fleet would take the arm-major driver, which has its
    // own audit below.
    let mut eng = Engine::new(EngineConfig {
        contention: Contention::new(1, 0.5),
        ingress_mbps: Some(200.0),
        select_batch: SelectBatch::Off,
        ..Default::default()
    });
    let audit_rounds = 512;
    for i in 0..16 {
        let env = ans::simulator::Environment::simple(zoo::vgg16(), 10.0 + i as f64, 20 + i as u64);
        let pol = LinUcb::paper_default(1_000_000);
        eng.add_session(Box::new(pol), env, FrameSource::uniform());
    }
    eng.reserve(64 + audit_rounds);
    eng.run(64); // warm-up: scratch + record buffers at capacity
    let before = allocations();
    eng.run(audit_rounds);
    let delta = allocations() - before;
    println!(
        "{:<44} {} allocs over {} rounds x 16 sessions",
        "alloc/engine_lockstep_steady_state", delta, audit_rounds
    );
    assert_eq!(delta, 0, "steady-state engine rounds must not allocate");

    // The same lockstep audit through the ARM-MAJOR batched select
    // (ISSUE 8): an all-μLinUCB fleet under the default `--select-batch
    // auto` resolves to the batched driver, whose per-round scratch
    // (theta arenas, score matrix, plans, gathered update tiles) is
    // pre-sized by `Engine::reserve` — so the steady state must stay
    // exactly zero allocations per round, same bar as the scalar path.
    let mut beng = Engine::new(EngineConfig {
        contention: Contention::new(1, 0.5),
        ingress_mbps: Some(200.0),
        ..Default::default()
    });
    let baudit_rounds = 512;
    for i in 0..16 {
        let env = ans::simulator::Environment::simple(zoo::vgg16(), 10.0 + i as f64, 80 + i as u64);
        let pol = LinUcb::paper_default(1_000_000);
        beng.add_session(Box::new(pol), env, FrameSource::uniform());
    }
    assert_eq!(
        beng.select_batch_effective(),
        "on",
        "auto must resolve to the arm-major driver for an all-store-backed fleet"
    );
    beng.reserve(64 + baudit_rounds);
    beng.run(64); // warm-up: batch scratch arenas at capacity
    let before = allocations();
    beng.run(baudit_rounds);
    let delta = allocations() - before;
    println!(
        "{:<44} {} allocs over {} rounds x 16 sessions",
        "alloc/engine_armmajor_steady_state", delta, baudit_rounds
    );
    assert_eq!(delta, 0, "arm-major batched rounds must not allocate");

    // And through the queue-aware event path: per round, the engine now
    // additionally computes the pre-round forecast, writes per-arm
    // predicted waits + the widened context dimensions, and resolves the
    // event-clock counterfactual oracle per frame — all of which must
    // stay allocation-free in steady state.
    let mut qeng = Engine::new(EngineConfig {
        contention: Contention::new(1, 0.25),
        scheduler: SchedulerConfig {
            batch_window_ms: 4.0,
            max_batch: 8,
            ..SchedulerConfig::event(AdmissionPolicy::Fifo)
        },
        queue_signal: QueueSignal::Full,
        ..Default::default()
    });
    let qaudit_rounds = 256;
    for i in 0..16 {
        let env = ans::simulator::Environment::simple(zoo::vgg16(), 10.0 + i as f64, 40 + i as u64);
        let pol = LinUcb::paper_default(1_000_000);
        qeng.add_session(Box::new(pol), env, FrameSource::uniform());
    }
    qeng.reserve(64 + qaudit_rounds);
    qeng.run(64); // warm-up: event-queue heaps + scratch at capacity
    let before = allocations();
    qeng.run(qaudit_rounds);
    let delta = allocations() - before;
    println!(
        "{:<44} {} allocs over {} rounds x 16 sessions",
        "alloc/engine_queue_aware_steady_state", delta, qaudit_rounds
    );
    assert_eq!(delta, 0, "queue-aware select/realize must not allocate");

    // The same queue-aware round with event tracing ENABLED: every
    // submit/admit/batch/drain/refresh event lands in a preallocated
    // ring (overwriting the oldest once full), so the steady-state round
    // must stay exactly zero-alloc with telemetry on — the ISSUE 7
    // acceptance bar.
    let mut teng = Engine::new(EngineConfig {
        contention: Contention::new(1, 0.25),
        scheduler: SchedulerConfig {
            batch_window_ms: 4.0,
            max_batch: 8,
            ..SchedulerConfig::event(AdmissionPolicy::Fifo)
        },
        queue_signal: QueueSignal::Full,
        trace_capacity: 4096,
        ..Default::default()
    });
    let taudit_rounds = 256;
    for i in 0..16 {
        let env = ans::simulator::Environment::simple(zoo::vgg16(), 10.0 + i as f64, 60 + i as u64);
        let pol = LinUcb::paper_default(1_000_000);
        teng.add_session(Box::new(pol), env, FrameSource::uniform());
    }
    teng.reserve(64 + taudit_rounds);
    teng.run(64); // warm-up: rings were preallocated at construction
    let before = allocations();
    teng.run(taudit_rounds);
    let delta = allocations() - before;
    println!(
        "{:<44} {} allocs over {} rounds x 16 sessions",
        "alloc/engine_traced_steady_state", delta, taudit_rounds
    );
    assert_eq!(delta, 0, "traced engine rounds must not allocate");
    assert!(
        !teng.drain_trace().is_empty(),
        "the traced audit must actually have recorded events"
    );

    // And the SoA policy store's batched cross-session round directly:
    // arm-major predict + confidence over the packed arenas, one batched
    // Sherman–Morrison update and downdate (which also exercises the
    // in-arena Cholesky refresh every 64 ops), plus an explicit
    // refresh_batch — all against pre-sized slot arenas and caller
    // buffers, so the steady state must be exactly zero allocations.
    // (The engine audits above already cover this path end-to-end —
    // every resident session's ridge state now lives in the store — but
    // this section pins the batch kernels in isolation.)
    let store_sessions = 16usize;
    let mut store = PolicyStore::with_capacity(CONTEXT_DIM, store_sessions);
    let prior = RidgeState::new(CONTEXT_DIM, 0.01);
    for i in 0..store_sessions {
        store.push_slot();
        store.slot_mut(i).load_from(&prior);
    }
    let mut srng = Rng::new(0x5A0A);
    let tile: Vec<f64> =
        (0..store_sessions * CONTEXT_DIM).map(|_| srng.uniform(0.0, 1.0)).collect();
    let ysb: Vec<f64> = (0..store_sessions).map(|_| srng.uniform(10.0, 500.0)).collect();
    let mut pred = vec![0.0; store_sessions];
    let mut conf = vec![0.0; store_sessions];
    let store_round = |store: &mut PolicyStore, pred: &mut [f64], conf: &mut [f64], t: usize| {
        store.predict_batch(&tile, pred);
        store.confidence_batch(&tile, conf);
        store.update_batch(&tile, &ysb);
        store.downdate_batch(&tile, &ysb);
        if t % 128 == 0 {
            store.refresh_batch();
        }
    };
    for t in 0..64 {
        store_round(&mut store, &mut pred, &mut conf, t); // warm-up
    }
    let before = allocations();
    let store_rounds = 4096usize;
    for t in 64..64 + store_rounds {
        store_round(&mut store, &mut pred, &mut conf, t);
    }
    let delta = allocations() - before;
    println!(
        "{:<44} {} allocs over {} rounds x {} slots",
        "alloc/policy_store_batch_steady_state", delta, store_rounds, store_sessions
    );
    assert_eq!(delta, 0, "batched SoA store round must not allocate");

    // The open-world churn audit (ISSUE 9): a steady-state round that
    // ADMITS a new session, HIBERNATES sessions whose duty burst ends
    // (policy cold-pack into a pooled byte arena), and WAKES sessions
    // from cold storage — with [`OpenWorld::prepare`] having pre-built
    // shells and pre-sized arenas, buckets, and engine envelopes — must
    // perform exactly zero heap allocations, same bar as a closed-world
    // round.
    {
        use ans::coordinator::OpenWorld;
        use ans::simulator::scenario::ChurnSchedule;

        let churn_builder: ans::coordinator::openworld::SessionBuilder = Box::new(|g| {
            let env = ans::simulator::Environment::simple(
                zoo::vgg16(),
                10.0 + (g % 8) as f64,
                700 + g,
            );
            let pol: Box<dyn Policy> = Box::new(LinUcb::paper_default(1_000_000));
            (pol, env, FrameSource::uniform())
        });
        // 64 live, 8-round duty period with 1-round bursts (~8 sleeps +
        // 8 wakes per boundary), one admission per round, no departures
        // inside the audit window (min lifespan 100 > warm-up + 1).
        let mut world = OpenWorld::new(
            EngineConfig {
                contention: Contention::new(1, 0.5),
                ingress_mbps: Some(200.0),
                ..Default::default()
            },
            ChurnSchedule::new(0xC0FFEE, 64, 1.0, 200, 0.125).with_period(8),
            churn_builder,
        );
        let churn_warm = 33usize;
        world.run(churn_warm);
        // The prepare contract: shells, arenas, buckets, and engine
        // envelopes pre-sized for the horizon — rounds inside it are
        // allocation-free.  (Wake shells are consumed per cycle, so a
        // server re-prepares as its horizon advances.)
        world.prepare(2);
        let s0 = world.stats();
        let before = allocations();
        world.round();
        let delta = allocations() - before;
        let s1 = world.stats();
        assert!(s1.admissions > s0.admissions, "audited round must admit a session");
        assert!(s1.hibernates > s0.hibernates, "audited round must hibernate a session");
        assert!(s1.wakes > s0.wakes, "audited round must wake a session");
        println!(
            "{:<44} {} allocs over 1 churn round ({} admit, {} hibernate, {} wake)",
            "alloc/openworld_churn_round",
            delta,
            s1.admissions - s0.admissions,
            s1.hibernates - s0.hibernates,
            s1.wakes - s0.wakes,
        );
        assert_eq!(
            delta, 0,
            "a prepared churn round (admission + hibernation + wake) must not allocate"
        );
    }

    b.write_csv("hotpath.csv").expect("writing bench_results/hotpath.csv");
}
